"""repro: Design in Tiles (DiT) — automated GEMM deployment for tile-based
many-PE accelerators, reproduced and retargeted to TPU pods in JAX."""
__version__ = "1.0.0"
