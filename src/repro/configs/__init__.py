from repro.configs import (deepseek_moe_16b, deepseek_v2_236b, gemma_2b,
                           olmo_1b, phi3_vision_4_2b, phi4_mini_3_8b,
                           qwen3_14b, seamless_m4t_medium, xlstm_1_3b,
                           zamba2_1_2b)
from repro.configs.registry import (SHAPES, SUBQUADRATIC, cells, get_config,
                                    list_archs, smoke_config)

__all__ = ["SHAPES", "SUBQUADRATIC", "cells", "get_config", "list_archs",
           "smoke_config"]
