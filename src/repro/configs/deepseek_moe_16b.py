"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("deepseek-moe-16b")
def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944,                      # dense-layer FFN (first layer)
        vocab=102400,
        n_experts=64, n_shared_experts=2, moe_top_k=6, moe_d_ff=1408,
        n_dense_layers=1,
        tie_embeddings=False,
    )


@register("deepseek-moe-16b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256,
        n_experts=8, n_shared_experts=2, moe_top_k=2, moe_d_ff=48,
        n_dense_layers=1,
        tie_embeddings=False,
    )
