"""Architecture registry: the 10 assigned architectures (exact configs from
the brief, [source] tags inline) + reduced smoke variants + the paper's own
GEMM benchmark shapes. `--arch <id>` everywhere resolves through here."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.models.common import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    return sorted(a for a in _REGISTRY if not a.endswith("-smoke"))


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small layers/width/experts/tables."""
    return get_config(f"{name}-smoke")


# -- shape suite (the brief's per-arch input shapes) -------------------------

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# archs with sub-quadratic decode state run long_500k; pure full-attention
# archs skip it (DESIGN.md §4 'Shape skips').
SUBQUADRATIC = {"zamba2-1.2b", "xlstm-1.3b"}


def cells(arch: str) -> List[str]:
    out = []
    for shape in SHAPES:
        if shape == "long_500k" and arch not in SUBQUADRATIC:
            continue
        out.append(shape)
    return out
