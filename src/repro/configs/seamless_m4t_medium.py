"""seamless-m4t-medium [audio] — encoder-decoder transformer backbone; the
speech frontend is a STUB (input_specs supplies precomputed frame embeddings)
[arXiv:2308.11596; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("seamless-m4t-medium")
def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=256206,
        is_encoder_decoder=True, n_encoder_layers=12,
        frontend="audio_stub", n_prefix=960,       # audio frames per utterance
        norm="layernorm", act="gelu",
        tie_embeddings=True,
    )


@register("seamless-m4t-medium-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256,
        is_encoder_decoder=True, n_encoder_layers=2,
        frontend="audio_stub", n_prefix=24,
        norm="layernorm", act="gelu",
    )
