"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("zamba2-1.2b")
def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000,
        block_pattern="mamba2_hybrid", ssm_state=64, mamba_headdim=64,
        hybrid_attn_every=6,
        tie_embeddings=True,
    )


@register("zamba2-1.2b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256,
        block_pattern="mamba2_hybrid", ssm_state=16, mamba_headdim=16,
        hybrid_attn_every=2,
    )
