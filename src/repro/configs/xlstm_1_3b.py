"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, d_ff=0 (the mixer carries the
up/down projections) [arXiv:2405.04517; unverified]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("xlstm-1.3b")
def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        block_pattern="xlstm", slstm_every=8,      # 7:1 mLSTM:sLSTM
        tie_embeddings=True,
    )


@register("xlstm-1.3b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=256,
        block_pattern="xlstm", slstm_every=2,
    )
