"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("deepseek-v2-236b")
def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12288,                      # dense-layer FFN (first layer)
        vocab=102400,
        attn="mla", kv_lora_rank=512, q_lora_rank=1536,
        rope_head_dim=64, nope_head_dim=128,
        n_experts=160, n_shared_experts=2, moe_top_k=6, moe_d_ff=1536,
        n_dense_layers=1,
        tie_embeddings=False,
    )


@register("deepseek-v2-236b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        attn="mla", kv_lora_rank=32, q_lora_rank=48,
        rope_head_dim=8, nope_head_dim=16,
        n_experts=8, n_shared_experts=2, moe_top_k=2, moe_d_ff=32,
        n_dense_layers=1,
        tie_embeddings=False,
    )
