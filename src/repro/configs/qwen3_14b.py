"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("qwen3-14b")
def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab=151936,
        qk_norm=True, head_dim=128,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


@register("qwen3-14b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=256, qk_norm=True, head_dim=16,
        tie_embeddings=False,
    )
