"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1) [arXiv:2403.08295; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("gemma-2b")
def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
        d_ff=16384, vocab=256000,
        head_dim=256, act="geglu",
        tie_embeddings=True,
    )


@register("gemma-2b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=160, vocab=256, head_dim=32, act="geglu",
    )
