"""olmo-1b [dense] — non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("olmo-1b")
def full() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304,
        norm="nonparam_ln", act="swiglu",
        tie_embeddings=True,
    )


@register("olmo-1b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256, norm="nonparam_ln",
    )
