"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend STUB
(input_specs supplies precomputed patch embeddings)
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("phi-3-vision-4.2b")
def full() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064,
        frontend="vision_stub", n_prefix=576,      # 24x24 CLIP patches
        tie_embeddings=True,
    )


@register("phi-3-vision-4.2b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256,
        frontend="vision_stub", n_prefix=16,
    )
