"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
from repro.configs.registry import register
from repro.models.common import ModelConfig


@register("phi4-mini-3.8b")
def full() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=200064,
        tie_embeddings=True,
    )


@register("phi4-mini-3.8b-smoke")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=256,
    )
