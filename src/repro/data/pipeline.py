"""Deterministic synthetic data pipeline.

Every batch is a pure function of (step, host shard): `batch(step)` needs no
iterator state, which buys the fault-tolerance properties DESIGN.md §5 claims
for free — any restarted/elastic/straggling host can jump to step N without
replay, and two hosts can never disagree about batch contents. Tokens follow a
Zipf-ish mixture with enough structure (copy runs, local n-gram statistics)
that a real LM's loss decreases measurably within a few hundred steps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    # this host's shard of the global batch (elastic: recompute on resize)
    host_index: int = 0
    n_hosts: int = 1
    seed: int = 1234


class SyntheticLM:
    """Stateless synthetic LM corpus: batch = f(step)."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global batch must divide by host count")
        self.cfg = cfg
        self.per_host = cfg.global_batch // cfg.n_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.uint64(cfg.seed) + np.uint64(step) * np.uint64(1_000_003)
            + np.uint64(cfg.host_index) * np.uint64(7_777_777))
        b, s = self.per_host, cfg.seq_len
        # Zipf-distributed base stream
        ranks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        tokens = ranks % cfg.vocab
        # inject copy runs so there is learnable structure (needs room)
        if s > 20:
            n_runs = max(1, s // 64)
            for i in range(b):
                for _ in range(n_runs):
                    start = rng.integers(0, s - 16)
                    length = int(rng.integers(4, 16))
                    src = rng.integers(0, max(1, s - length))
                    tokens[i, start:start + length] = tokens[i, src:src + length]
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "targets": tokens[:, 1:].astype(np.int32),
        }

    def reshard(self, host_index: int, n_hosts: int) -> "SyntheticLM":
        """Elastic resize: same corpus, new host partition (DESIGN.md §5)."""
        return SyntheticLM(dataclasses.replace(
            self.cfg, host_index=host_index, n_hosts=n_hosts))


class PrefetchingLoader:
    """Host-side prefetch thread over a stateless source — overlaps batch
    synthesis with device execution (the §3.3.1 overlap idea at host level)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        import queue
        import threading
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put((step, source.batch(step)), timeout=0.2)
                    step += 1
                except Exception:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
