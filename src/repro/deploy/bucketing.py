"""Shape bucketing: serve unseen GEMM shapes from nearby tuned plans.

Serving traffic produces a long tail of GEMM shapes (every batch size x
sequence length x projection), but mapping decisions transfer across nearby
shapes — the schedule space is driven by aspect ratio and magnitude, not the
exact dimension values. The bucketing layer exploits that:

- `bucket_of` rounds each dimension up to a power of two (capped, so one
  bucket covers the whole saturated regime) — the canonical shape a tuning
  run is amortized over;
- `nearest_tuned` ranks already-tuned shapes by log-space distance;
- `adapt` re-targets a tuned schedule to the requested shape, keeping the
  (grid, dataflow, remap) decision and re-deriving shape-dependent pieces
  (K-chunk clamp, default layouts), rejecting the transfer when the tiling
  does not legally divide the new shape.

A bucketed plan is always *checked* (legality via `build_program`, cost via
`estimate`) before being served; only the candidate *search* is skipped.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional

from repro.core.schedule import GEMMShape, Schedule
from repro.hw.config import AcceleratorConfig


def next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class BucketingPolicy:
    """Knobs for the bucketed-serving path."""
    # dimensions round up to pow-2 buckets, saturating at this cap (a GEMM
    # with M = 1M tokens schedules like M = dim_cap: the grid just iterates).
    dim_cap: int = 8192
    # maximum sum of per-dim |log2| distances for a transfer to be attempted.
    max_distance: float = 3.0
    # how many adaptable sources a bucketed lookup prices before picking the
    # best (each costs one build+estimate, not a search).
    max_transfers: int = 3
    # bucketed estimate must be within (1 + tolerance) of a fresh tune for
    # `validate_transfer` to bless it (used by tests and refinement).
    tolerance: float = 0.25


def bucket_of(shape: GEMMShape,
              policy: BucketingPolicy = BucketingPolicy()) -> GEMMShape:
    """The canonical tuning shape for `shape` (pow-2 rounded, capped)."""
    return GEMMShape(m=min(next_pow2(shape.m), policy.dim_cap),
                     n=min(next_pow2(shape.n), policy.dim_cap),
                     k=min(next_pow2(shape.k), policy.dim_cap))


def distance(a: GEMMShape, b: GEMMShape) -> float:
    """Log-space L1 distance between two shapes (0 == identical)."""
    return (abs(math.log2(a.m / b.m)) + abs(math.log2(a.n / b.n))
            + abs(math.log2(a.k / b.k)))


def nearest_tuned(shape: GEMMShape, pool: Iterable[GEMMShape],
                  policy: BucketingPolicy = BucketingPolicy()
                  ) -> List[GEMMShape]:
    """Tuned shapes worth attempting a transfer from, nearest first."""
    ranked = sorted((cand for cand in pool if cand != shape),
                    key=lambda cand: distance(shape, cand))
    return [cand for cand in ranked
            if distance(shape, cand) <= policy.max_distance]


def adapt(schedule: Schedule, shape: GEMMShape,
          hw: AcceleratorConfig) -> Optional[Schedule]:
    """Re-target `schedule` to `shape`; None if the tiling doesn't transfer.

    Keeps the tuned decision (logical grid, iteration factors, dataflow,
    remap, buffering) and re-derives the shape-dependent parts: the K-chunk
    is re-clamped to the new K_local, and pinned layouts are dropped so
    `resolve_layouts` regenerates defaults for the new matrix shapes. Only
    tiling divisibility is checked here — the caller prices the result with
    `build_program` + `estimate`, which performs the full legality check
    (L1 capacity included) as a side effect.
    """
    tiling = schedule.tiling
    if tiling.gk == 0 or shape.k % tiling.gk:
        return None
    k_local = shape.k // tiling.gk
    tk = min(tiling.tk, k_local)
    while k_local % tk and tk > 1:
        tk //= 2                  # largest pow-2 chunk dividing K_local
    if k_local % tk:
        return None
    cand = dataclasses.replace(
        schedule, shape=shape,
        tiling=dataclasses.replace(tiling, tk=tk),
        layouts=None)
    try:
        cand.tiling.validate(shape, hw.n_tiles)
    except ValueError:
        return None
    return cand


def transfer_candidates(shape: GEMMShape, pool: Iterable[GEMMShape],
                        policy: BucketingPolicy = BucketingPolicy()
                        ) -> List[GEMMShape]:
    """Search order for a bucketed lookup: the exact bucket first, then the
    nearest tuned neighbours."""
    bucket = bucket_of(shape, policy)
    pool = list(pool)
    out: List[GEMMShape] = [s for s in (bucket,) if s in pool and s != shape]
    out += [s for s in nearest_tuned(shape, pool, policy) if s not in out]
    return out
