"""Shape-bucket-aware continuous batching for the serving harness (jax-free).

Live traffic hands the server a ragged stream of requests; every admission
decision fixes the M dimension of the batched GEMMs the model stack will
dispatch. A naive batcher admits whatever is pending and fragments the shape
stream into a long tail of M values — each one a cold `plan_cached` miss
(online analytic tune) plus a fresh XLA compile. The bucket-aware policy
admits so that M always lands on a warmed pow-2 bucket
(`deploy/bucketing.py`'s canonical tuning shapes): request groups are chosen
to maximize bucket fill, decode batches are padded up to the next pow-2, and
every dispatch stays on the pre-tuned, pre-compiled pool.

Pieces:

- `Request` — one traffic-trace entry (tenant, arrival, prompt/gen lengths,
  SLO deadline). Produced by `launch/traffic.py`'s seeded generator.
- `Batch` — one admitted unit of work: the requests, the actual token rows,
  and the GEMM M the engine will run (`m == rows` under FIFO; the padded
  pow-2 bucket under the bucket policy; `utilization` is the fill ratio).
- `BatchPolicy` — admission knobs: `mode` ("bucket" | "fifo"), `max_batch`,
  the `min_fill` a bucket-mode batch should reach before admission, and the
  `max_wait_s` aging bound after which the oldest request is admitted
  regardless (the no-starvation guarantee).
- `ContinuousBatcher` — per-tenant FIFO queues with oldest-head-first tenant
  selection. Invariants (tests/test_serving.py asserts them, hypothesis
  included): every submitted request is admitted exactly once, admission
  order within a tenant is arrival order, and no tenant starves (the tenant
  with the oldest waiting head request is always served next).
- `decode_m` / `bucket_pool` — the decode-side bucket rule and the warmed
  pow-2 M pool a harness should pre-tune (see docs/serving.md).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional, Tuple

from repro.deploy.bucketing import next_pow2

BATCH_MODES = ("bucket", "fifo")


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request of the replayed trace."""
    rid: int
    tenant: str
    arrival_s: float
    prompt_len: int
    gen_len: int
    # total-latency SLO, relative to arrival (TTFT + decode budget); inf
    # means best-effort. The harness derives it from the tenant spec.
    slo_s: float = math.inf

    @property
    def tokens(self) -> int:
        return self.prompt_len + self.gen_len

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.slo_s


@dataclasses.dataclass(frozen=True)
class Batch:
    """One admitted unit of engine work (a batched prefill or decode round).

    `rows` is the real token-row count (sum of prompt lengths for prefill,
    active sequence count for decode); `m` is the GEMM M dimension the
    engine runs — equal to `rows` under FIFO, the padded pow-2 bucket under
    the bucket policy.
    """
    tenant: str
    phase: str                    # "prefill" | "decode"
    requests: Tuple[Request, ...]
    rows: int
    m: int

    @property
    def utilization(self) -> float:
        """Useful fraction of the admitted GEMM's M rows (1.0 = no pad)."""
        return self.rows / self.m if self.m else 0.0


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Admission knobs for the continuous batcher."""
    mode: str = "bucket"          # "bucket" | "fifo" (the naive baseline)
    # most requests one prefill batch / decode round may serve.
    max_batch: int = 8
    # bucket mode: don't admit a batch filling its bucket below this ratio
    # while the oldest pending request is younger than `max_wait_s` — wait
    # for more arrivals instead. FIFO mode ignores it (admit immediately).
    min_fill: float = 0.75
    # aging bound: once the oldest pending request has waited this long the
    # best available batch is admitted regardless of fill (no starvation).
    max_wait_s: float = 0.05
    # pow-2 saturation cap for padded Ms (mirrors BucketingPolicy.dim_cap).
    dim_cap: int = 8192

    def __post_init__(self) -> None:
        if self.mode not in BATCH_MODES:
            raise ValueError(f"mode must be one of {BATCH_MODES}, "
                             f"got {self.mode!r}")
        if not 0.0 < self.min_fill <= 1.0:
            raise ValueError(f"min_fill must be in (0, 1], got {self.min_fill}")

    def bucket_m(self, rows: int) -> int:
        """The padded pow-2 GEMM M for `rows` token rows."""
        return min(next_pow2(max(1, rows)), self.dim_cap)


def decode_m(n_active: int, policy: BatchPolicy) -> int:
    """GEMM M of one decode round over `n_active` sequences: the exact count
    under FIFO, the padded pow-2 bucket under the bucket policy."""
    if policy.mode == "fifo":
        return n_active
    return policy.bucket_m(n_active)


def bucket_pool(max_rows: int, policy: BatchPolicy) -> List[int]:
    """Every M the bucket policy can emit for workloads up to `max_rows`
    token rows: the pow-2 ladder 1..bucket_m(max_rows). This is the pool a
    harness warms (and pre-compiles) so bucket-mode admission never leaves
    tuned plans."""
    top = policy.bucket_m(max_rows)
    return [1 << i for i in range(top.bit_length())]


class ContinuousBatcher:
    """Per-tenant FIFO queues + bucket-aware (or naive) admission."""

    def __init__(self, policy: BatchPolicy = BatchPolicy()) -> None:
        self.policy = policy
        self._queues: Dict[str, Deque[Request]] = {}
        self._order: List[str] = []          # tenant registration order
        self.submitted = 0
        self.admitted = 0

    # -- intake --------------------------------------------------------------

    def submit(self, req: Request) -> None:
        q = self._queues.get(req.tenant)
        if q is None:
            q = self._queues[req.tenant] = collections.deque()
            self._order.append(req.tenant)
        q.append(req)
        self.submitted += 1

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def oldest_arrival(self) -> Optional[float]:
        heads = [q[0].arrival_s for q in self._queues.values() if q]
        return min(heads) if heads else None

    def next_decision_s(self) -> Optional[float]:
        """The virtual time at which a currently-declined admission becomes
        forced by aging (None when nothing is pending)."""
        oldest = self.oldest_arrival()
        return None if oldest is None else oldest + self.policy.max_wait_s

    # -- admission -----------------------------------------------------------

    def _pick_tenant(self) -> Optional[str]:
        """Tenant with the oldest waiting head request (ties broken by
        registration order) — the no-starvation rule."""
        best = None
        for name in self._order:
            q = self._queues[name]
            if q and (best is None
                      or q[0].arrival_s < self._queues[best][0].arrival_s):
                best = name
        return best

    def _best_prefix(self, q: Deque[Request]) -> Tuple[int, int, int]:
        """(k, rows, m) of the admission prefix the policy picks from `q`.

        FIFO: everything up to `max_batch`, exact rows. Bucket: the FIFO
        prefix (order within a tenant is never reordered) whose padded
        pow-2 bucket is best filled — ties go to the larger batch.
        """
        limit = min(len(q), self.policy.max_batch)
        if self.policy.mode == "fifo":
            rows = sum(q[i].prompt_len for i in range(limit))
            return limit, rows, max(1, rows)
        best = None                 # (k, rows, m, utilization)
        rows = 0
        for k in range(1, limit + 1):
            rows += q[k - 1].prompt_len
            m = self.policy.bucket_m(rows)
            util = rows / m
            if best is None or util >= best[3]:
                best = (k, rows, m, util)
        return best[0], best[1], best[2]

    def next_prefill(self, now: float) -> Optional[Batch]:
        """The next prefill batch to run at virtual time `now`, or None.

        None means either nothing is pending, or the bucket policy prefers
        to wait for a better fill (only while the oldest pending request is
        younger than `max_wait_s` — `next_decision_s` says when the engine
        should ask again).
        """
        tenant = self._pick_tenant()
        if tenant is None:
            return None
        q = self._queues[tenant]
        k, rows, m = self._best_prefix(q)
        if self.policy.mode == "bucket" and rows / m < self.policy.min_fill \
                and now - q[0].arrival_s < self.policy.max_wait_s:
            return None
        reqs = tuple(q.popleft() for _ in range(k))
        self.admitted += len(reqs)
        return Batch(tenant=tenant, phase="prefill", requests=reqs,
                     rows=rows, m=m)
