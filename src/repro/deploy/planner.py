"""Planner: the deployment front-end over the autotuner.

One `Planner` owns (hardware, cache, search knobs) and answers every "how do
I run this GEMM" question a serving stack asks:

- `plan(shape)` — the dispatch path. Exact cache hit returns instantly (no
  candidate enumeration); a miss first tries a bucketed transfer from a
  nearby tuned shape (one build + one estimate instead of a full search, and
  the exact shape is queued for background refinement); only a cold shape
  with no usable neighbour pays a full `tune`.
- `plan_cached(shape)` — the serving path: hit, else bucketed transfer,
  else an *online* tune over the closed-form analytic shortlist
  (core/analytic.py — bounded candidate count, recorded as an `analytic`
  plan and queued for background refinement). A cold shape never pays the
  full candidate search at trace time.
- `batch_tune(shapes)` — warm the cache for a whole workload in one pass,
  deduping shapes first.
- `refine_pending()` / `refine_async(executor)` — the background-refinement
  hook: re-tune bucket- and analytic-served shapes for real and upgrade
  their cache entries when the fresh schedule is no worse.

`model_workload` extracts the deduplicated GEMM shapes of one model
config's forward pass (projections, FFN, MoE experts, LM head) so a server
can warm its planner from the architectures it will host.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.analytic import DEFAULT_SHORTLIST_K, analytic_tune
from repro.core.attention import attn_tune
from repro.core.autotuner import tune
from repro.core.schedule import AttnShape, GEMMShape, build_program
from repro.hw.config import AcceleratorConfig
from repro.obs.trace import maybe_span
from repro.sim.perf import estimate

from repro.deploy.bucketing import BucketingPolicy, transfer_candidates, adapt
from repro.deploy.cache import PlanCache
from repro.deploy.plan import (DeploymentPlan, SOURCE_ANALYTIC,
                               SOURCE_BUCKETED, SOURCE_TUNED,
                               hw_fingerprint, plan_admissible,
                               plan_from_tuning, search_variant)


class Planner:
    def __init__(self, hw: AcceleratorConfig,
                 cache: Optional[PlanCache] = None,
                 elem_bytes: Optional[int] = None,
                 max_candidates: int = 48,
                 dataflows: Optional[List[str]] = None,
                 store_stage_options: Tuple[int, ...] = (1, 4),
                 policy: BucketingPolicy = BucketingPolicy(),
                 on_plan: Optional[Callable[[DeploymentPlan], None]] = None,
                 calibration=None,
                 online_tune: bool = True,
                 analytic_k: int = DEFAULT_SHORTLIST_K):
        self.hw = hw
        self.cache = cache if cache is not None else PlanCache()
        self.elem_bytes = (elem_bytes if elem_bytes is not None
                           else hw.tile.elem_bytes)
        self.max_candidates = max_candidates
        # [] would mean 'unrestricted' to the tuner but 'nothing admissible'
        # to the cache check — normalize it to None so both agree.
        self.dataflows = list(dataflows) if dataflows else None
        self.store_stage_options = store_stage_options
        self.policy = policy
        self.on_plan = on_plan
        # measured-calibration profile (sim/calibrate.CalibrationProfile):
        # every tune this planner runs ranks candidates by the calibrated
        # cost, and a trusted profile widens the DEFAULT search space. A
        # profile fitted for different hardware is refused outright — a
        # mis-keyed profile must not silently re-rank another machine.
        if calibration is not None \
                and calibration.hw_digest != hw_fingerprint(hw):
            raise ValueError(
                f"calibration profile {calibration.describe()} was fitted "
                f"for hw digest {calibration.hw_digest}, not "
                f"{hw_fingerprint(hw)} ({hw.name})")
        self.calibration = calibration
        # the ranking regime this planner serves plans under: a trusted
        # profile's digest, else "" (analytical prior — an UNTRUSTED profile
        # changes nothing, so it shares the prior's regime). Cached plans
        # ranked under a different regime are re-tuned, not served: without
        # this, a warmed cache dir would make a later calibration a silent
        # no-op for every already-cached shape.
        self._calibration_digest = (calibration.digest()
                                    if calibration is not None
                                    and calibration.fit_ok else "")
        # restricted searches live under their own cache-key variant so they
        # never collide with (or clobber) the unrestricted winners.
        self.variant = search_variant(dataflows)
        # online (analytic) tuning of plan_cached misses: price the
        # closed-form shortlist instead of returning None. `analytic_k`
        # bounds the per-miss work.
        self.online_tune = online_tune
        self.analytic_k = analytic_k
        self._pending: List[GEMMShape] = []

    # -- dispatch path ------------------------------------------------------

    def plan(self, shape: GEMMShape,
             allow_bucketed: bool = True) -> DeploymentPlan:
        cached = self.cache.get(shape, self.elem_bytes, self.hw,
                                self.variant)
        if cached is not None and self._admissible(cached) \
                and cached.source != SOURCE_ANALYTIC:
            # an analytic entry (online shortlist winner) is served on the
            # dispatch path but never satisfies `plan`: here paying the full
            # search is the point, and the fresh tune replaces the entry.
            return cached
        if isinstance(shape, AttnShape):
            # the fused-attention candidate space IS the closed-form menu —
            # there is no bigger search to pay, so the warm-up path caches
            # the same winner as SOURCE_TUNED (it satisfies `plan` on
            # re-lookup and never needs refinement)
            plan = self._attn_plan(shape, source=SOURCE_TUNED)
            if plan is None:
                raise RuntimeError(f"no legal flat-attention candidate for "
                                   f"{shape.describe()} on {self.hw.name}")
            return plan
        if allow_bucketed:
            bucketed = self._bucketed_plan(shape)
            if bucketed is not None:
                return bucketed
        return self._tune_and_cache(shape)

    def plan_cached(self, shape: GEMMShape) -> Optional[DeploymentPlan]:
        """`plan` minus the full tune — the serving dispatch path.

        Exact cache hit, else a bucketed transfer, else an online tune over
        the closed-form analytic shortlist (both of which queue the shape
        for background refinement), else None. A cold shape never pays the
        full candidate search at trace time; when even the analytic path
        finds no legal candidate the caller (`models.matmul.pmm`) falls
        back to the auto dataflow and counts the miss.
        """
        cached = self.cache.get(shape, self.elem_bytes, self.hw,
                                self.variant)
        if cached is not None and self._admissible(cached):
            return cached
        bucketed = self._bucketed_plan(shape)
        if bucketed is not None:
            return bucketed
        return self._analytic_plan(shape)

    def _admissible(self, plan) -> bool:
        """Defensive check on top of the variant keying — the shared rule
        lives in `deploy.plan.plan_admissible` (tune_cached applies the
        same one)."""
        return plan_admissible(plan, self.dataflows,
                               self._calibration_digest)

    def _cost(self, report) -> float:
        """The ranking cost this planner compares plans by: the trusted
        profile's calibrated prediction, else the analytical total."""
        if self._calibration_digest:
            return self.calibration.predict(report)
        return report.total_time

    def _bucketed_plan(self, shape: GEMMShape) -> Optional[DeploymentPlan]:
        if isinstance(shape, AttnShape):
            # attention plans never transfer between shapes: legality is
            # all-or-nothing divisibility, and the candidate menu is cheap
            # enough to price exactly per shape
            return None
        pool = list(self.cache.shapes_for(self.elem_bytes, self.hw,
                                          self.variant))
        best = None     # (time, schedule, report)
        priced = 0
        for src_shape in transfer_candidates(shape, pool, self.policy):
            if priced >= self.policy.max_transfers:
                break
            src = self.cache.peek(src_shape, self.elem_bytes, self.hw,
                                  self.variant)
            if src is None or not self._admissible(src):
                continue
            if src.source != SOURCE_TUNED:
                # never seed transfers from anything but a full tune.
                # Bucketed sources would compound the per-hop tolerance
                # loss unboundedly (each hop can lose up to `tolerance`
                # and the expected-time guard scales the *source's* time);
                # analytic sources are unrefined shortlist winners — the
                # full search never validated them, so adapting one would
                # chain a second unvalidated approximation onto the first.
                continue
            adapted = adapt(src.schedule, shape, self.hw)
            if adapted is None:
                continue
            try:
                report = estimate(build_program(adapted, self.hw), self.hw)
            except (ValueError, KeyError):
                continue
            priced += 1
            # what this shape *should* cost if the transfer preserved the
            # source's efficiency: the source's time scaled by the work
            # ratio (compute- and memory-bound lower bounds).
            scale = max(shape.flops() / src_shape.flops(),
                        shape.min_bytes(self.elem_bytes)
                        / src_shape.min_bytes(self.elem_bytes))
            expected = src.report.total_time * scale
            if report.total_time > (1.0 + self.policy.tolerance) * expected:
                # this transfer lost too much efficiency (e.g. the tuned
                # grid's tiles no longer fill the engine) — but another
                # source may still pass its own bound, so keep looking.
                continue
            # rank surviving transfers by the planner's ranking cost (the
            # tolerance guard above stays analytical: it compares the
            # analytical estimate against an analytically-scaled bound)
            if best is None or self._cost(report) < best[0]:
                best = (self._cost(report), adapted, report)
        if best is None:
            return None
        plan = plan_from_tuning(shape, self.hw, best[1], best[2],
                                source=SOURCE_BUCKETED,
                                variant=self.variant,
                                calibration_digest=self._calibration_digest)
        self.cache.put(plan)
        self._pending.append(shape)
        self._emit(plan)
        return plan

    def _analytic_plan(self, shape: GEMMShape) -> Optional[DeploymentPlan]:
        """Online tune: price the closed-form shortlist for a cold shape.

        Bounded work (`analytic_k` candidates instead of the full
        enumeration), so the serving path can afford it on a miss. The
        winner is cached as an `analytic` plan — served like any other,
        but queued for background refinement, never a transfer source, and
        replaced outright the first time `plan` sees the shape.
        """
        if not self.online_tune:
            return None
        if isinstance(shape, AttnShape):
            return self._attn_plan(shape)
        with maybe_span("planner.online_tune", m=shape.m, n=shape.n,
                        k=shape.k) as span_args:
            try:
                res = analytic_tune(shape, self.hw, dataflows=self.dataflows,
                                    elem_bytes=self.elem_bytes,
                                    k=self.analytic_k,
                                    store_stage_options=self.store_stage_options,
                                    calibration=self.calibration)
            except RuntimeError:
                # no legal shortlist candidate — the caller counts the miss
                if span_args is not None:
                    span_args["resolved"] = False
                return None
            if span_args is not None:
                span_args.update(resolved=True,
                                 candidates=res.candidates_tried,
                                 schedule=res.schedule.describe())
        plan = plan_from_tuning(shape, self.hw, res.schedule, res.report,
                                candidates_tried=res.candidates_tried,
                                source=SOURCE_ANALYTIC, variant=self.variant,
                                calibration_digest=res.calibration)
        self.cache.put(plan)
        self._pending.append(shape)
        self._emit(plan)
        return plan

    def _attn_plan(self, shape: AttnShape,
                   source: str = SOURCE_ANALYTIC) -> Optional[DeploymentPlan]:
        """Resolve a fused-attention shape through the closed-form candidate
        menu (core/attention.attn_tune — composition × kv_chunk, priced by
        `sim.perf.estimate_attention` under the planner's calibration).

        The space is tiny, so the same bounded pricing serves both the
        serving path (`plan_cached` → SOURCE_ANALYTIC) and the warm-up path
        (`plan` → SOURCE_TUNED). Never queued for refinement — there is no
        fuller search to validate against. Returns None when no fused
        candidate is legal (the pattn funnel falls back to the unfused
        path and counts the miss).
        """
        with maybe_span("planner.online_tune", attn=shape.describe(),
                        sq=shape.sq, skv=shape.skv, h=shape.h) as span_args:
            try:
                res = attn_tune(shape, self.hw, elem_bytes=self.elem_bytes,
                                calibration=self.calibration)
            except RuntimeError:
                if span_args is not None:
                    span_args["resolved"] = False
                return None
            if span_args is not None:
                span_args.update(resolved=True,
                                 candidates=res.candidates_tried,
                                 schedule=res.schedule.describe())
        plan = plan_from_tuning(shape, self.hw, res.schedule, res.report,
                                candidates_tried=res.candidates_tried,
                                source=source, variant=self.variant,
                                calibration_digest=res.calibration)
        self.cache.put(plan)
        self._emit(plan)
        return plan

    def _tune_and_cache(self, shape: GEMMShape) -> DeploymentPlan:
        plan = self._tune_shape(shape)
        self.cache.put(plan)
        self._emit(plan)
        return plan

    def _emit(self, plan: DeploymentPlan) -> None:
        if self.on_plan is not None:
            self.on_plan(plan)

    # -- batch warming ------------------------------------------------------

    def batch_tune(self, shapes: Sequence[GEMMShape],
                   allow_bucketed: bool = False,
                   skip_illegal: bool = False
                   ) -> Dict[GEMMShape, DeploymentPlan]:
        """Tune a whole workload's (deduplicated) shapes into the cache.

        `skip_illegal` swallows per-shape "no legal schedule" errors —
        a dataflow-restricted planner (e.g. a Fig. 6c-only search) may have
        shapes with no legal candidate at all; those stay unplanned and the
        dispatch path counts them as fallbacks instead of aborting the warm.
        """
        out: Dict[GEMMShape, DeploymentPlan] = {}
        for shape in dict.fromkeys(shapes):
            try:
                out[shape] = self.plan(shape, allow_bucketed=allow_bucketed)
            except RuntimeError:
                if not skip_illegal:
                    raise
        return out

    # -- background refinement ---------------------------------------------

    @property
    def pending_refinements(self) -> Tuple[GEMMShape, ...]:
        return tuple(self._pending)

    def refine_pending(self, limit: Optional[int] = None
                       ) -> List[Tuple[GEMMShape, float, float]]:
        """Full-tune bucket-served shapes; upgrade entries that improve.

        Returns (shape, bucketed_cost, tuned_cost) per refinement — the
        validation record of the bucketing shortcut. Costs are the
        planner's ranking costs (calibrated when a trusted profile is
        installed), so refinement never un-picks a calibrated winner for
        looking worse under the analytical prior.
        """
        n = len(self._pending) if limit is None else min(limit,
                                                         len(self._pending))
        todo, self._pending = self._pending[:n], self._pending[n:]
        out = []
        for shape in todo:
            out.append(self._refine_one(shape))
        return out

    def refine_async(self, executor) -> List["object"]:
        """Submit pending refinements to a concurrent.futures executor."""
        todo, self._pending = self._pending, []
        return [executor.submit(self._refine_one, shape) for shape in todo]

    def _refine_one(self, shape: GEMMShape
                    ) -> Tuple[GEMMShape, float, float]:
        current = self.cache.peek(shape, self.elem_bytes, self.hw,
                                  self.variant)
        fresh = self._tune_shape(shape)
        old_t = self._cost(current.report) if current else float("inf")
        # <= so a tie still records the validation: the entry becomes
        # SOURCE_TUNED and can seed future transfers.
        if self._cost(fresh.report) <= old_t:
            self.cache.put(fresh)
            self._emit(fresh)
        elif current is not None and current.source == SOURCE_ANALYTIC:
            # the shortlist winner beat the (bounded) full search — the
            # search still validated it, so upgrade its provenance: it may
            # now seed transfers and satisfies `plan` like any tuned entry.
            upgraded = dataclasses.replace(current, source=SOURCE_TUNED)
            self.cache.put(upgraded)
            self._emit(upgraded)
        return (shape, old_t, self._cost(fresh.report))

    def _tune_shape(self, shape: GEMMShape) -> DeploymentPlan:
        res = tune(shape, self.hw, dataflows=self.dataflows,
                   elem_bytes=self.elem_bytes,
                   max_candidates=self.max_candidates,
                   store_stage_options=self.store_stage_options,
                   calibration=self.calibration)
        return plan_from_tuning(shape, self.hw, res.schedule, res.report,
                                candidates_tried=res.candidates_tried,
                                source=SOURCE_TUNED, variant=self.variant,
                                calibration_digest=res.calibration)

    # -- validation ---------------------------------------------------------

    def transfer_ratio(self, shape: GEMMShape) -> float:
        """estimated(bucketed plan) / estimated(fresh tune) for `shape`.

        Used by tests and the cold/warm benchmark to check the bucketing
        tolerance; runs a full tune, so it is NOT a dispatch-path call.
        """
        plan = self.plan(shape)
        fresh = self._tune_shape(shape)
        return plan.report.total_time / fresh.report.total_time


# ---------------------------------------------------------------------------
# Workload extraction
# ---------------------------------------------------------------------------

def moe_dispatch_geometry(tokens: int, cfg, dp: int = 1) -> Tuple[int, int]:
    """(dispatch groups, per-group expert capacity) for `tokens` tokens.

    Pure-int mirror of `repro.models.moe._dp_groups` / `_capacity` (the
    deploy layer must stay importable without jax, so the logic is duplicated
    here; tests/test_plan_routing.py pins the two in sync by comparing this
    prediction against the shapes moe.apply_moe actually records). `dp` is
    the data-parallel shard count the dispatch groups align to (1 when no
    mesh is installed).
    """
    group_tokens = 512                      # moe._GROUP_TOKENS
    if tokens % dp:
        dp = 1
    g = dp
    while tokens % (g * 2) == 0 and tokens // (g * 2) >= group_tokens:
        g *= 2
    tl = tokens // g
    cap = max(int(tl * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts), 4)
    return g, cap


def model_workload(cfg, batch: int, seq: int,
                   kind: str = "prefill", dp: int = 1) -> List[GEMMShape]:
    """Deduplicated projection GEMMs of one forward pass of `cfg`.

    `cfg` is a `repro.models.common.ModelConfig` (duck-typed so the deploy
    layer stays importable without jax). Token dimension M is batch*seq for
    train/prefill and batch for decode; weights supply (K, N). `dp` is the
    data-parallel shard count of the mesh the model will trace under (1
    when no mesh is installed) — it feeds the MoE dispatch-group geometry,
    which aligns groups to the DP axes.

    These are the shapes the model stack actually traces through
    `models.matmul.pmm` — every entry is checked against the recorded
    (tag, GEMMShape) pairs of a real forward pass in
    tests/test_plan_routing.py, so launcher warm-ups tune exactly the GEMMs
    that will be dispatched — including the encoder-decoder stacks
    (encoder self-attention blocks over the frame prefix, and the decoder
    cross-attention K/V projections that re-run over the encoder output on
    every decode step).
    """
    tokens = batch * seq if kind in ("train", "prefill") else batch
    tokens = max(1, tokens)
    d, hd = cfg.d_model, cfg.hd
    pattern = getattr(cfg, "block_pattern", "attn")
    shapes: List[GEMMShape] = []

    def gemm(m, n, k):
        if m > 0 and n > 0 and k > 0:
            shapes.append(GEMMShape(m, n, k))

    # modality frontend stub: the learned (d x d) projection applied to the
    # precomputed patch/frame embeddings (models.model.forward tags it
    # frontend.proj). Decode steps never re-run the frontend. For the VLM
    # frontends the projected prefix is prepended to the token sequence, so
    # every downstream block GEMM runs at batch*(n_prefix + seq) rows.
    front = getattr(cfg, "frontend", "none")
    n_prefix = getattr(cfg, "n_prefix", 0)
    if front in ("vision_stub", "audio_stub") and n_prefix \
            and kind in ("train", "prefill"):
        gemm(batch * n_prefix, d, d)                    # frontend.proj
        if not getattr(cfg, "is_encoder_decoder", False):
            tokens += batch * n_prefix                  # prefix joins the seq

    # attention projections (xlstm stacks have no attention blocks)
    if pattern == "xlstm":
        d_inner = 2 * d
        gemm(tokens, 2 * d_inner, d)                    # mLSTM up
        gemm(tokens, d_inner, d_inner)                  # q / k / v (identical)
        gemm(tokens, 2 * cfg.n_heads, d)                # i/f gate pre-acts
        gemm(tokens, d, d_inner)                        # mLSTM down
        gemm(tokens, 4 * d, d)                          # sLSTM in
        gemm(tokens, d, d)                              # sLSTM out
    elif getattr(cfg, "attn", "gqa") == "mla":
        if cfg.q_lora_rank:
            gemm(tokens, cfg.q_lora_rank, d)
        qdim = cfg.n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
        gemm(tokens, qdim, cfg.q_lora_rank or d)
        # the model runs the KV down-projection and the shared rotary key as
        # two separate matmuls (attention.mla_attention), not one fused GEMM
        gemm(tokens, cfg.kv_lora_rank, d)
        gemm(tokens, cfg.rope_head_dim, d)
        if kind == "decode":
            # absorbed form: W_uk folds into the query and W_uv un-absorbs
            # the latent output — n_heads per-head (r x dn) contractions
            # each, no K/V up-projection ever runs. The shape list is a
            # set (coverage is membership-based); the per-head multiplicity
            # lives in the observed counts: attention.mla_attention records
            # these two with count=n_heads per call.
            gemm(tokens, cfg.kv_lora_rank, cfg.nope_head_dim)
            gemm(tokens, cfg.nope_head_dim, cfg.kv_lora_rank)
        else:
            # naive form: up-project K and V (identical shapes) from c_kv
            gemm(tokens, cfg.n_heads * cfg.nope_head_dim, cfg.kv_lora_rank)
        gemm(tokens, d, cfg.n_heads * cfg.nope_head_dim)
    else:
        gemm(tokens, cfg.n_heads * hd, d)               # Q
        gemm(tokens, cfg.n_kv_heads * hd, d)            # K and V (identical)
        gemm(tokens, d, cfg.n_heads * hd)               # O
    # encoder-decoder stacks (seamless): the encoder runs full
    # self-attention blocks over the frame prefix, and every decoder layer
    # adds cross-attention whose Q/O run at decoder rows (identical to the
    # self-attention shapes above) while K/V project the ENCODER output —
    # and therefore re-run at encoder rows on every decode step too.
    if getattr(cfg, "is_encoder_decoder", False):
        enc_tokens = batch * n_prefix
        if enc_tokens:
            if kind in ("train", "prefill"):
                # encoder self-attention blocks (prefill/train only; decode
                # consumes the precomputed encoder output)
                gemm(enc_tokens, cfg.n_heads * hd, d)       # enc Q
                gemm(enc_tokens, cfg.n_kv_heads * hd, d)    # enc K and V
                gemm(enc_tokens, d, cfg.n_heads * hd)       # enc O
                if cfg.d_ff:
                    gemm(enc_tokens, cfg.d_ff, d)           # enc gate / up
                    gemm(enc_tokens, d, cfg.d_ff)           # enc down
            # decoder cross-attention K/V over the encoder output (every
            # kind — decode recomputes them each step, attention.py has no
            # cross-attention cache)
            gemm(enc_tokens, cfg.n_kv_heads * hd, d)
    # SSM mixer projections of the hybrid stacks (zamba2); the shared
    # attention block above supplies the attn/FFN shapes
    if pattern == "mamba2_hybrid":
        d_inner = 2 * d
        nh = d_inner // cfg.mamba_headdim
        gemm(tokens, 2 * d_inner + 2 * cfg.ssm_state + nh, d)   # fused in
        gemm(tokens, d, d_inner)                                # out
    # FFN (dense layers) and MoE experts
    if cfg.d_ff and pattern != "xlstm":
        gemm(tokens, cfg.d_ff, d)                       # gate / up (identical)
        gemm(tokens, d, cfg.d_ff)                       # down
    if cfg.n_experts and cfg.moe_top_k:
        # per-expert M is the capacity-bounded dispatch buffer, not the mean
        # token count: each (group, expert) GEMM runs at exactly `cap` rows
        _, cap = moe_dispatch_geometry(tokens, cfg, dp=dp)
        gemm(tokens, cfg.n_experts, d)                  # router
        gemm(cap, cfg.moe_d_ff, d)                      # expert gate / up
        gemm(cap, d, cfg.moe_d_ff)                      # expert down
        if getattr(cfg, "n_shared_experts", 0):
            sh_ff = cfg.moe_d_ff * cfg.n_shared_experts
            gemm(tokens, sh_ff, d)
            gemm(tokens, d, sh_ff)
    # LM head
    gemm(tokens, cfg.vocab, d)
    return list(dict.fromkeys(shapes))


def workload_coverage(predicted: Sequence[GEMMShape],
                      observed: Sequence[GEMMShape]) -> Dict[str, object]:
    """Cross-validate `model_workload` against what the model actually ran.

    `observed` is the deduplicated shape list a `GemmContext` recorded
    (`stats.observed_shapes()`). Returns the predicted shapes that never
    executed (`missing` — warm-up tuned something useless), the executed
    shapes the prediction did not cover (`extra` — warm-up skipped real
    traffic), and the covered fraction of the observed workload.
    """
    pred, obs = set(predicted), set(observed)
    covered = len(obs & pred) / len(obs) if obs else 1.0
    return {
        "missing": sorted(pred - obs, key=lambda s: (s.m, s.n, s.k)),
        "extra": sorted(obs - pred, key=lambda s: (s.m, s.n, s.k)),
        "covered": covered,
    }


def arch_workload(cfg, shape_name: str) -> List[GEMMShape]:
    """`model_workload` with (batch, seq, kind) pulled from the registry's
    shape suite (the same cells the dry-run sweep enumerates)."""
    from repro.configs.registry import SHAPES
    spec = SHAPES[shape_name]
    return model_workload(cfg, batch=spec["global_batch"],
                          seq=spec["seq_len"], kind=spec["kind"])
