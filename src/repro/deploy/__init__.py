"""Deployment-plan subsystem: persistent schedule cache + shape bucketing +
batch planner, turning one-shot autotuning into a reusable serving pipeline.

    from repro.deploy import Planner, PlanCache

    planner = Planner(hw, cache=PlanCache("results/plan_cache"))
    planner.batch_tune(model_workload(cfg, batch=8, seq=4096))   # cold, once
    plan = planner.plan(shape)                                   # warm: O(1)
"""
from repro.deploy.batcher import (BATCH_MODES, Batch, BatchPolicy,
                                  ContinuousBatcher, Request, bucket_pool,
                                  decode_m)
from repro.deploy.bucketing import (BucketingPolicy, adapt, bucket_of,
                                    distance, nearest_tuned, next_pow2,
                                    transfer_candidates)
from repro.deploy.cache import CacheStats, PlanCache, plan_key
from repro.deploy.plan import (DeploymentPlan, PLAN_SCHEMA_VERSION,
                               SOURCE_BUCKETED, SOURCE_TUNED, hw_fingerprint,
                               plan_from_tuning, schedule_from_dict,
                               schedule_to_dict, search_variant)
from repro.deploy.planner import (Planner, arch_workload, model_workload,
                                  moe_dispatch_geometry, workload_coverage)

__all__ = [
    "BATCH_MODES", "Batch", "BatchPolicy", "BucketingPolicy", "CacheStats",
    "ContinuousBatcher", "DeploymentPlan", "PLAN_SCHEMA_VERSION",
    "PlanCache", "Planner", "Request", "SOURCE_BUCKETED", "SOURCE_TUNED",
    "adapt", "arch_workload", "bucket_of", "bucket_pool", "decode_m",
    "distance", "hw_fingerprint", "model_workload", "moe_dispatch_geometry",
    "nearest_tuned", "next_pow2", "plan_from_tuning", "plan_key",
    "schedule_from_dict", "schedule_to_dict", "search_variant",
    "transfer_candidates", "workload_coverage",
]
