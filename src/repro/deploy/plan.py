"""DeploymentPlan: the persistable artifact of one autotuning run.

A plan bundles everything dispatch needs to reuse a tuning decision without
re-running the search: the winning `Schedule`, the cost-model `PerfReport`
that justified it, and a fingerprint of the `AcceleratorConfig` it was tuned
for (a plan is only valid on the hardware it was priced against). Plans are
JSON documents with an explicit schema version so a persisted cache survives
code evolution — readers reject versions they don't understand instead of
silently deserializing garbage.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
from typing import Any, Dict

from repro.core import layout as layout_lib
from repro.core.remap import ClusterRemap
from repro.core.schedule import (ATTN_DATAFLOW, AttnSchedule, AttnShape,
                                 GEMMShape, InnerKernel, Schedule, Tiling)
from repro.hw.config import AcceleratorConfig
from repro.sim.perf import PerfReport

# Bump whenever the serialized layout below changes incompatibly.
PLAN_SCHEMA_VERSION = 1

# How the plan was produced: a full candidate search, adapted from a nearby
# tuned bucket, or priced online from the closed-form analytic shortlist
# (core/analytic.py). Bucketed and analytic plans are candidates for
# background refinement — only a full search settles the question.
SOURCE_TUNED = "tuned"
SOURCE_BUCKETED = "bucketed"
SOURCE_ANALYTIC = "analytic"


@functools.lru_cache(maxsize=64)
def hw_fingerprint(hw: AcceleratorConfig) -> str:
    """Stable digest of every field that affects schedule legality or cost.

    Cached per config instance value (frozen dataclass, hashable) — this is
    on the per-GEMM dispatch path, so it must not re-serialize every call.
    """
    blob = json.dumps(dataclasses.asdict(hw), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Schedule <-> dict
# ---------------------------------------------------------------------------

def _layout_to_dict(lay: layout_lib.DataLayout) -> Dict[str, Any]:
    return {"split": [lay.split.grid_m, lay.split.grid_n],
            "placement": [lay.placement.tm, lay.placement.tn],
            "n_channels": lay.n_channels, "phase": lay.phase}


def _layout_from_dict(d: Dict[str, Any]) -> layout_lib.DataLayout:
    return layout_lib.DataLayout(
        split=layout_lib.SplitScheme(*d["split"]),
        placement=layout_lib.PlacementScheme(*d["placement"]),
        n_channels=d["n_channels"], phase=d["phase"])


def schedule_to_dict(sched: Schedule) -> Dict[str, Any]:
    if isinstance(sched, AttnSchedule):
        # discriminated by "kind" — absent means GEMM, so pre-attention
        # plan files keep loading under the same schema version
        s = sched.shape
        return {
            "kind": "attention",
            "shape": [s.b, s.sq, s.skv, s.h, s.hkv, s.d, s.dv,
                      int(s.causal)],
            "composition": sched.composition,
            "kv_chunk": sched.kv_chunk,
            "dataflow": sched.dataflow,
            "elem_bytes": sched.elem_bytes,
            "elem_dtype": sched.elem_dtype,
        }
    return {
        "shape": [sched.shape.m, sched.shape.n, sched.shape.k],
        "tiling": [sched.tiling.gm, sched.tiling.gn, sched.tiling.gk,
                   sched.tiling.iter_m, sched.tiling.iter_n, sched.tiling.tk],
        "dataflow": sched.dataflow,
        "remap": ([list(sched.remap.physical), list(sched.remap.logical)]
                  if sched.remap else None),
        "layouts": ({k: _layout_to_dict(v) for k, v in sched.layouts.items()}
                    if sched.layouts else None),
        "double_buffer": sched.double_buffer,
        "store_stages": sched.store_stages,
        "inner": list(sched.inner),
        "reduce_owner": sched.reduce_owner,
        "elem_bytes": sched.elem_bytes,
        "acc_bytes": sched.acc_bytes,
        "elem_dtype": sched.elem_dtype,
        "inner_kernel": (sched.inner_kernel.to_dict()
                         if sched.inner_kernel is not None else None),
        "overlap": sched.overlap,
    }


def schedule_from_dict(d: Dict[str, Any]) -> Schedule:
    if d.get("kind") == "attention":
        b, sq, skv, h, hkv, dd, dv, causal = d["shape"]
        return AttnSchedule(
            shape=AttnShape(b=int(b), sq=int(sq), skv=int(skv), h=int(h),
                            hkv=int(hkv), d=int(dd), dv=int(dv),
                            causal=bool(causal)),
            composition=d["composition"],
            kv_chunk=int(d["kv_chunk"]),
            dataflow=d.get("dataflow", ATTN_DATAFLOW),
            elem_bytes=int(d["elem_bytes"]),
            elem_dtype=d.get("elem_dtype", ""))
    remap = None
    if d.get("remap"):
        phys, logi = d["remap"]
        remap = ClusterRemap(tuple(phys), tuple(logi))
    layouts = None
    if d.get("layouts"):
        layouts = {k: _layout_from_dict(v) for k, v in d["layouts"].items()}
    return Schedule(
        shape=GEMMShape(*d["shape"]),
        tiling=Tiling(*d["tiling"]),
        dataflow=d["dataflow"],
        remap=remap,
        layouts=layouts,
        double_buffer=d["double_buffer"],
        store_stages=d["store_stages"],
        inner=tuple(d["inner"]),
        reduce_owner=d["reduce_owner"],
        elem_bytes=d["elem_bytes"],
        acc_bytes=d["acc_bytes"],
        # two-level fields: absent in pre-inner-kernel plans (same schema
        # version — readers tolerate their absence, writers always emit)
        elem_dtype=d.get("elem_dtype", ""),
        inner_kernel=(InnerKernel.from_dict(d["inner_kernel"])
                      if d.get("inner_kernel") else None),
        overlap=bool(d.get("overlap", False)),
    )


# ---------------------------------------------------------------------------
# The plan artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeploymentPlan:
    schedule: Schedule
    report: PerfReport
    hw_name: str
    hw_digest: str
    source: str = SOURCE_TUNED
    candidates_tried: int = 0
    schema_version: int = PLAN_SCHEMA_VERSION
    # search-space variant this plan was tuned under ("" = unrestricted).
    # Part of the cache key: a dataflow-restricted search must not collide
    # with (or clobber) the unrestricted winner for the same shape.
    variant: str = ""
    # digest of the trusted CalibrationProfile that ranked the candidate
    # search ("" = ranked by the analytical prior). Provenance, not a cache
    # key: a calibrated re-tune intentionally replaces the prior's winner.
    calibration_digest: str = ""

    @property
    def shape(self) -> GEMMShape:
        return self.schedule.shape

    @property
    def elem_bytes(self) -> int:
        return self.schedule.elem_bytes

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "hw_name": self.hw_name,
            "hw_digest": self.hw_digest,
            "source": self.source,
            "candidates_tried": self.candidates_tried,
            "variant": self.variant,
            "calibration_digest": self.calibration_digest,
            "schedule": schedule_to_dict(self.schedule),
            "report": self.report.to_dict(),
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DeploymentPlan":
        version = d.get("schema_version")
        if version != PLAN_SCHEMA_VERSION:
            raise ValueError(f"plan schema version {version!r} not supported "
                             f"(reader is at {PLAN_SCHEMA_VERSION})")
        return cls(
            schedule=schedule_from_dict(d["schedule"]),
            report=PerfReport.from_dict(d["report"]),
            hw_name=d["hw_name"],
            hw_digest=d["hw_digest"],
            source=d.get("source", SOURCE_TUNED),
            candidates_tried=d.get("candidates_tried", 0),
            schema_version=version,
            variant=d.get("variant", ""),
            calibration_digest=d.get("calibration_digest", ""),
        )

    @classmethod
    def from_json(cls, text: str) -> "DeploymentPlan":
        return cls.from_dict(json.loads(text))

    def digest(self) -> str:
        """Stable id of this exact plan (schedule + report + provenance) —
        recorded in dispatch spans / run reports so a serve trace can be
        matched to the persisted artifact that produced it."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:12]

    def valid_for(self, hw: AcceleratorConfig) -> bool:
        return self.hw_digest == hw_fingerprint(hw)

    def describe(self) -> str:
        s = self.shape
        head = (s.describe() if hasattr(s, "skv")
                else f"{s.m}x{s.n}x{s.k}")
        return (f"plan[{head} e{self.elem_bytes} {self.source} "
                f"@{self.hw_name}] {self.schedule.describe()} "
                f"est={self.report.total_time*1e6:.1f}us")


def plan_from_tuning(shape: GEMMShape, hw: AcceleratorConfig,
                     schedule: Schedule, report: PerfReport,
                     candidates_tried: int = 0,
                     source: str = SOURCE_TUNED,
                     variant: str = "",
                     calibration_digest: str = "") -> DeploymentPlan:
    assert schedule.shape == shape
    return DeploymentPlan(schedule=schedule, report=report, hw_name=hw.name,
                          hw_digest=hw_fingerprint(hw), source=source,
                          candidates_tried=candidates_tried, variant=variant,
                          calibration_digest=calibration_digest)


def plan_admissible(plan: DeploymentPlan, dataflows,
                    calibration_digest: str) -> bool:
    """THE cache-hit admissibility rule, shared by `deploy.Planner` and
    `core.autotuner.tune_cached` so the two entry points cannot disagree:
    a plan outside the caller's dataflow space (hand-edited cache dir), or
    ranked under a different calibration regime (analytical plans after a
    trusted profile landed, or vice versa), is a miss — it gets re-tuned
    and replaced, never silently served."""
    df = plan.schedule.dataflow
    # a dataflow-restricted GEMM search space does not constrain attention
    # plans — flat_attention is its own (single-dataflow) space, priced by
    # the same calibration regime
    if dataflows is not None and df not in dataflows and df != ATTN_DATAFLOW:
        return False
    return plan.calibration_digest == calibration_digest


def search_variant(dataflows) -> str:
    """Cache-key tag for a restricted dataflow search ('' = unrestricted).

    An empty list counts as unrestricted — that is what the autotuner's
    `dataflows or [...]` default makes it mean.
    """
    if not dataflows:
        return ""
    return hashlib.sha256(",".join(sorted(dataflows)).encode()).hexdigest()[:8]
