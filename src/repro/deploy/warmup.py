"""Shared launcher warm-up: one place for the plan-cache CLI flags and the
planner construction both `launch.serve` and `launch.sweep` use, so the two
entry points cannot drift apart.
"""
from __future__ import annotations

import time
from typing import List, Sequence

from repro.core.schedule import GEMMShape

from repro.deploy.bucketing import bucket_of
from repro.deploy.cache import PlanCache
from repro.deploy.planner import Planner


def add_plan_args(ap) -> None:
    """The launcher flags controlling plan-cache warm-up."""
    ap.add_argument("--plan-cache", default="results/plan_cache",
                    help="directory for persisted deployment plans")
    ap.add_argument("--plan-grid", type=int, nargs=2, default=(4, 4),
                    metavar=("R", "C"),
                    help="pod grid the plans are tuned for")
    ap.add_argument("--plan-candidates", type=int, default=12,
                    help="autotuner search width during warm-up")
    ap.add_argument("--skip-plan-warmup", action="store_true")
    ap.add_argument("--no-online-tune", action="store_true",
                    help="disable online (analytic-shortlist) tuning of "
                         "plan_cached misses — cold shapes fall back to the "
                         "auto dataflow instead")


def build_planner(cache_dir: str, grid, max_candidates: int,
                  dataflows=None, calibration=None,
                  online_tune: bool = True) -> Planner:
    """A Planner on the pod-view accelerator with a persistent cache.

    `dataflows` restricts the candidate search (the restricted plans live
    under their own cache variant) — `dryrun --route-dataflows` uses it to
    force e.g. Fig. 6c schedules into the cache for the routed proof.

    A persisted calibration profile for this hardware fingerprint (written
    by `dryrun --calibrate` next to the plans) is loaded automatically, so
    every launcher that warms from the cache dir tunes with the measured
    cost model; pass `calibration` explicitly to override (or
    `calibration=False` to force the analytical prior).

    `online_tune=False` (the `--no-online-tune` flag) disables the analytic
    shortlist on `plan_cached` misses, restoring the pre-online behaviour
    where cold shapes degrade to the auto dataflow.
    """
    from repro.hw.config import tpu_pod_as_accelerator
    from repro.sim.calibrate import load_profile
    hw = tpu_pod_as_accelerator(tuple(grid))
    if calibration is None:
        calibration = load_profile(cache_dir, hw)
    elif calibration is False:
        calibration = None
    return Planner(hw, cache=PlanCache(cache_dir),
                   max_candidates=max_candidates,
                   dataflows=dataflows,
                   calibration=calibration,
                   online_tune=online_tune)


def warm_buckets(planner: Planner,
                 workload: Sequence[GEMMShape]) -> List[GEMMShape]:
    """Batch-tune the deduplicated pow-2 buckets of a GEMM workload and
    print the one-line warm-up summary. Returns the bucket list."""
    t0 = time.time()
    buckets = list(dict.fromkeys(bucket_of(s, planner.policy)
                                 for s in workload))
    planner.batch_tune(buckets, skip_illegal=planner.dataflows is not None)
    print(f"plan cache: {len(dict.fromkeys(workload))} workload shapes -> "
          f"{len(buckets)} buckets warmed in {time.time()-t0:.2f}s on "
          f"{planner.hw.name} ({planner.cache.stats.describe()})",
          flush=True)
    return buckets
