"""PlanCache: persistent store of DeploymentPlans.

Keys are `(shape, elem_bytes, hw fingerprint, search variant)` — the exact
identity of a tuning problem. The cache is an in-memory dict backed (optionally) by a
directory of one-JSON-file-per-plan, so a warmed cache survives process
restarts and can be shipped alongside a model as a deployment artifact.

Invalidation is by construction: the hardware fingerprint is part of the
key, so plans tuned for a different `AcceleratorConfig` (or written by an
incompatible schema version) are never served — stale files are simply
ignored on load.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.schedule import GEMMShape
from repro.hw.config import AcceleratorConfig

from repro.deploy.plan import DeploymentPlan, hw_fingerprint

# (m, n, k, elem_bytes, hw_digest, variant) — variant tags a restricted
# search space ("" = unrestricted) so constrained tunes never collide with
# the unrestricted winner for the same shape. Attention shapes keep the
# 6-slot arity (iterators unpack keys positionally) but discriminate by a
# string first slot encoding the full AttnShape geometry.
Key = Tuple[object, int, int, int, str, str]


def plan_key(shape, elem_bytes: int, hw_digest: str,
             variant: str = "") -> Key:
    if hasattr(shape, "skv"):       # AttnShape
        tag = (f"attn_b{shape.b}_q{shape.sq}_kv{shape.skv}"
               f"_h{shape.h}x{shape.hkv}_d{shape.d}v{shape.dv}"
               f"_c{int(shape.causal)}")
        return (tag, 0, 0, elem_bytes, hw_digest, variant)
    return (shape.m, shape.n, shape.k, elem_bytes, hw_digest, variant)


def _filename(key: Key) -> str:
    m, n, k, eb, digest, variant = key
    tag = f"_v{variant}" if variant else ""
    head = m if isinstance(m, str) else f"m{m}_n{n}_k{k}"
    return f"{head}_e{eb}_{digest}{tag}.plan.json"


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0

    def describe(self) -> str:
        total = self.hits + self.misses
        rate = self.hits / total if total else 0.0
        return f"hits={self.hits} misses={self.misses} hit-rate={rate:.0%}"


class PlanCache:
    """In-memory plan store with optional on-disk persistence."""

    def __init__(self, cache_dir: Optional[str] = None):
        self.cache_dir = cache_dir
        self._mem: Dict[Key, DeploymentPlan] = {}
        self.stats = CacheStats()
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)
            self._load_dir()

    def _load_dir(self) -> None:
        for fname in sorted(os.listdir(self.cache_dir)):
            if not fname.endswith(".plan.json"):
                continue
            path = os.path.join(self.cache_dir, fname)
            try:
                with open(path) as f:
                    plan = DeploymentPlan.from_json(f.read())
            except (ValueError, KeyError, TypeError, OSError,
                    json.JSONDecodeError):
                continue   # corrupt, incompatible-schema, or unreadable file
            s = plan.shape
            key = plan_key(s, plan.elem_bytes, plan.hw_digest, plan.variant)
            self._mem[key] = plan

    # -- core API -----------------------------------------------------------

    def get(self, shape: GEMMShape, elem_bytes: int,
            hw: AcceleratorConfig,
            variant: str = "") -> Optional[DeploymentPlan]:
        plan = self.peek(shape, elem_bytes, hw, variant)
        if plan is None:
            self.stats.misses += 1
        else:
            self.stats.hits += 1
        return plan

    def peek(self, shape: GEMMShape, elem_bytes: int,
             hw: AcceleratorConfig,
             variant: str = "") -> Optional[DeploymentPlan]:
        """Lookup without touching hit/miss stats (internal probes)."""
        return self._mem.get(
            plan_key(shape, elem_bytes, hw_fingerprint(hw), variant))

    def put(self, plan: DeploymentPlan) -> None:
        key = plan_key(plan.shape, plan.elem_bytes, plan.hw_digest,
                       plan.variant)
        self._mem[key] = plan
        self.stats.puts += 1
        if self.cache_dir:
            path = os.path.join(self.cache_dir, _filename(key))
            # atomic publish so a concurrent reader never sees a torn file
            fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(plan.to_json())
                os.replace(tmp, path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise

    def contains(self, shape: GEMMShape, elem_bytes: int,
                 hw: AcceleratorConfig, variant: str = "") -> bool:
        """Membership check that does not perturb hit/miss stats."""
        key = plan_key(shape, elem_bytes, hw_fingerprint(hw), variant)
        return key in self._mem

    def shapes_for(self, elem_bytes: int, hw: AcceleratorConfig,
                   variant: str = "") -> Iterator[GEMMShape]:
        """Tuned shapes usable on `hw` — the bucketing layer's search pool."""
        digest = hw_fingerprint(hw)
        for (m, n, k, eb, d, v) in self._mem:
            if isinstance(m, str):
                continue        # attention plans are not bucketing seeds
            if eb == elem_bytes and d == digest and v == variant:
                yield GEMMShape(m, n, k)

    def plans(self) -> List[DeploymentPlan]:
        return list(self._mem.values())

    def clear(self) -> None:
        self._mem.clear()
        if self.cache_dir:
            for fname in os.listdir(self.cache_dir):
                if fname.endswith(".plan.json"):
                    os.unlink(os.path.join(self.cache_dir, fname))

    def __len__(self) -> int:
        return len(self._mem)
