"""Launcher-installed execution context for the model stack.

Two layers, both set by launchers before tracing and no-ops when absent:

1. **Activation-sharding context** (`set_mesh` / `constrain_tokens`): pins
   the residual stream's layout. Without explicit constraints XLA's sharding
   propagation may legally trade batch sharding for contraction sharding on
   FSDP weights (each device then computes the FULL batch through a weight
   slice — same matmul FLOPs, but every downstream op replicates over the
   data axis; observed 2-4x compute inflation on the production mesh).
   Pinning `(batch=dp, seq=None, d_model=None)` at every block boundary
   keeps the program in the intended DP x TP regime — this is DiT's
   data-layout control (paper §3.2) applied to activations.

2. **GEMM-routing context** (`set_gemm_context` / `GemmContext`): the mesh
   context extended into a full gemm context. It carries the device mesh plus
   the deployment `Planner` whose warmed plan cache decides how each model
   matmul executes; `repro.models.matmul.pmm` consults it at trace time and
   dispatches through `repro.core.gemm.dit_gemm`. The context also records
   every (tag, GEMMShape) the model actually traces — the ground truth that
   `repro.deploy.planner.model_workload` is cross-validated against — and
   keeps routing stats (exact hit / bucketed / analytic online-tune /
   fallback) for the launcher's shutdown report. With no context installed, `pmm` is exactly `x @ w`, so
   smoke tests and meshless tracing are unchanged.

See docs/architecture.md for the full routing path.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


# ---------------------------------------------------------------------------
# GEMM-routing context
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GemmStats:
    """Trace-time routing counters + the observed (tag, shape) workload.

    Counts are per *traced* `pmm` call (shapes are static, so each jit trace
    consults the planner once per callsite per layer group); `observed` maps
    (tag, GEMMShape) -> trace count and is the model-side record that
    `model_workload` predictions are checked against.
    """
    hits: int = 0          # served a fully-tuned plan
    bucketed: int = 0      # served a bucket-transferred plan
    analytic: int = 0      # served an online-tuned (analytic shortlist) plan
    fallback: int = 0      # no usable plan -> auto dataflow
    unrouted: int = 0      # recorded but not routed (no mesh in the context)
    unroutable: int = 0    # pmm calls that are not a single dense GEMM
    #                        (batched weights etc.) — recorded, never routed
    observed: Dict[Tuple[str, object], int] = dataclasses.field(
        default_factory=dict)
    # attention dispatches (pattn) keep a separate observed map: the GEMM
    # `observed` feeds `workload_coverage`/`observed_shapes`, whose consumers
    # sort on (m, n, k) and rebuild `GEMMShape(*shape)` — an AttnShape there
    # would crash them. Keys are (tag, AttnShape).
    attn_observed: Dict[Tuple[str, object], int] = dataclasses.field(
        default_factory=dict)
    # schedule->mesh lowering outcomes (repro.core.lower.ExecPlan): which
    # mode each plan-served matmul actually executed, and the
    # machine-readable reason for every degradation along the way
    modes: Dict[str, int] = dataclasses.field(default_factory=dict)
    degrades: Dict[str, int] = dataclasses.field(default_factory=dict)
    silent_degrades: int = 0   # auto executions with NO recorded reason
    #                            (structurally 0: every ExecPlan fallback
    #                            carries a reason; kept as the cross-check)

    def record(self, tag: str, shape, count: int = 1) -> None:
        """`count` > 1 logs one traced call that stands for `count` GEMMs of
        this shape (MLA's absorbed form runs n_heads per-head contractions
        in one einsum)."""
        key = (tag, shape)
        self.observed[key] = self.observed.get(key, 0) + count

    def record_attn(self, tag: str, shape) -> None:
        key = (tag, shape)
        self.attn_observed[key] = self.attn_observed.get(key, 0) + 1

    def record_lowering(self, exec_plan) -> None:
        """Count an ExecPlan's executed mode + its fallback-chain reasons."""
        self.modes[exec_plan.mode] = self.modes.get(exec_plan.mode, 0) + 1
        for fb in exec_plan.fallbacks:
            self.degrades[fb.reason] = self.degrades.get(fb.reason, 0) + 1
        if exec_plan.mode == "auto" and not exec_plan.fallbacks:
            self.silent_degrades += 1

    @property
    def routed(self) -> int:
        return self.hits + self.bucketed + self.analytic + self.fallback

    @property
    def resolved(self) -> int:
        """Calls that found a plan — cached, bucketed, or online-tuned
        (the hit-rate numerator)."""
        return self.hits + self.bucketed + self.analytic

    @property
    def resolve_rate(self) -> float:
        return self.resolved / self.routed if self.routed else 0.0

    def observed_shapes(self) -> List[object]:
        """Deduplicated GEMMShapes the model actually traced."""
        return list(dict.fromkeys(shape for (_, shape) in self.observed))

    def to_dict(self) -> Dict[str, object]:
        """JSON-able snapshot — the run report's `routing` section.

        Counters plus derived summaries (`calls`, `routed`, `resolve_rate`)
        so report consumers never recompute them, and the observed
        (tag, shape) workload as a stable list. `from_dict` round-trips it.
        """
        return {
            "calls": self.routed + self.unrouted,
            "routed": self.routed,
            "hits": self.hits,
            "bucketed": self.bucketed,
            "analytic": self.analytic,
            "fallback": self.fallback,
            "unrouted": self.unrouted,
            "unroutable": self.unroutable,
            "resolve_rate": self.resolve_rate,
            "modes": dict(sorted(self.modes.items())),
            "degrades": dict(sorted(self.degrades.items())),
            "silent_degrades": self.silent_degrades,
            "observed": [
                {"tag": tag,
                 "shape": ([int(s.m), int(s.n), int(s.k)]
                           if hasattr(s, "m") else list(s)),
                 "count": count}
                for (tag, s), count in self.observed.items()],
            "attn_observed": [
                {"tag": tag,
                 "shape": [int(s.b), int(s.sq), int(s.skv), int(s.h),
                           int(s.hkv), int(s.d), int(s.dv),
                           int(s.causal)],
                 "count": count}
                for (tag, s), count in self.attn_observed.items()],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "GemmStats":
        """Rebuild a stats object from `to_dict()` output (derived fields
        like `calls`/`routed`/`resolve_rate` are recomputed, not read)."""
        from repro.core.schedule import AttnShape, GEMMShape
        stats = cls(hits=int(d["hits"]), bucketed=int(d["bucketed"]),
                    analytic=int(d.get("analytic", 0)),
                    fallback=int(d["fallback"]), unrouted=int(d["unrouted"]),
                    unroutable=int(d.get("unroutable", 0)),
                    modes=dict(d.get("modes", {})),
                    degrades=dict(d.get("degrades", {})),
                    silent_degrades=int(d.get("silent_degrades", 0)))
        for rec in d.get("observed", []):
            key = (rec["tag"], GEMMShape(*rec["shape"]))
            stats.observed[key] = int(rec["count"])
        for rec in d.get("attn_observed", []):
            b, sq, skv, h, hkv, dd, dv, causal = rec["shape"]
            key = (rec["tag"], AttnShape(b, sq, skv, h, hkv, dd, dv,
                                         bool(causal)))
            stats.attn_observed[key] = int(rec["count"])
        return stats

    def describe(self) -> str:
        # render from the dict so the print and the run report cannot drift
        from repro.obs.report import describe_routing
        return describe_routing(self.to_dict())


@dataclasses.dataclass
class GemmContext:
    """What `pmm` needs to route a model matmul through `dit_gemm`.

    mesh=None makes the context record-only: every pmm call is logged in
    `stats.observed` but executes as plain `x @ w` (used by dry-runs and the
    workload cross-validation tests, which trace without devices to spare).
    """
    mesh: Optional[Mesh] = None
    planner: Optional[object] = None      # repro.deploy.Planner (duck-typed)
    row_axis: str = "data"
    col_axis: str = "model"
    stats: GemmStats = dataclasses.field(default_factory=GemmStats)


_GEMM_CTX: Optional[GemmContext] = None


def set_gemm_context(ctx: Optional[GemmContext]) -> None:
    global _GEMM_CTX
    _GEMM_CTX = ctx


def get_gemm_context() -> Optional[GemmContext]:
    return _GEMM_CTX


@contextlib.contextmanager
def gemm_context(ctx: GemmContext) -> Iterator[GemmContext]:
    """Scoped install (tests); launchers use set_gemm_context directly."""
    prev = _GEMM_CTX
    set_gemm_context(ctx)
    try:
        yield ctx
    finally:
        set_gemm_context(prev)


def _dp(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def constrain_tokens(x: jax.Array) -> jax.Array:
    """(B, S, D) or (B, S): batch over the DP axes when it divides."""
    if _MESH is None:
        return x
    dp = _dp(_MESH)
    size = 1
    for a in dp:
        size *= _MESH.shape[a]
    if x.shape[0] % size:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
