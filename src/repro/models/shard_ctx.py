"""Activation-sharding context: pins the residual stream's layout.

Without explicit constraints XLA's sharding propagation may legally trade
batch sharding for contraction sharding on FSDP weights (each device then
computes the FULL batch through a weight slice — same matmul FLOPs, but every
downstream op replicates over the data axis; observed 2-4x compute inflation
on the production mesh). Pinning `(batch=dp, seq=None, d_model=None)` at
every block boundary keeps the program in the intended DP x TP regime — this
is DiT's data-layout control (paper §3.2) applied to activations.

The mesh is set by the launcher before tracing; smoke tests that trace with
no mesh set are unaffected (constraints become no-ops).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def _dp(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def constrain_tokens(x: jax.Array) -> jax.Array:
    """(B, S, D) or (B, S): batch over the DP axes when it divides."""
    if _MESH is None:
        return x
    dp = _dp(_MESH)
    size = 1
    for a in dp:
        size *= _MESH.shape[a]
    if x.shape[0] % size:
        return x
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
