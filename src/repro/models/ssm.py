"""State-space / recurrent mixers: Mamba2 (SSD chunked scan), mLSTM and sLSTM
(xLSTM). These are the sub-quadratic blocks that make `long_500k` decode
feasible (DESIGN.md §4): training/prefill uses chunked-parallel forms, decode
carries O(1) recurrent state.

The paper's GEMM schedules apply to the in/out projections (regular GEMMs);
the scan itself is not a GEMM and is noted as out-of-scope for DiT scheduling
in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Params, dense_init
from repro.models.matmul import pmm

CHUNK = 128


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_params(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner = 2 * d
    n_heads = d_inner // cfg.mamba_headdim
    n = cfg.ssm_state
    ks = jax.random.split(key, 5)
    return {
        # fused in-projection: [z, x, B, C, dt]
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * n + n_heads, cfg.dtype),
        "conv": (jax.random.normal(ks[1], (4, d_inner + 2 * n), jnp.float32)
                 * 0.1).astype(cfg.dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "w_out": dense_init(ks[2], d_inner, d, cfg.dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, kernel 4. x: (B, S, C); state: (B, 3, C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out), xp[:, -(k - 1):]


def _ssd_chunked(xh: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, h0: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. xh: (B,S,H,P); dt: (B,S,H); a: (H) negative; b/c: (B,S,N).
    Returns (y: (B,S,H,P), final state (B,H,N,P))."""
    from repro.models import accounting
    bb, s, h, p = xh.shape
    n = b.shape[-1]
    L = min(accounting.chunk(CHUNK), s)
    nc = s // L
    assert nc * L == s, f"seq {s} must divide by chunk {L}"

    la = dt * a[None, None, :]                       # log-decay per step (B,S,H)
    la = la.reshape(bb, nc, L, h)
    xc = xh.reshape(bb, nc, L, h, p)
    dtc = dt.reshape(bb, nc, L, h)
    bc = b.reshape(bb, nc, L, n)
    cc = c.reshape(bb, nc, L, n)

    cum = jnp.cumsum(la, axis=2)                     # (B,nc,L,H) inclusive
    # within-chunk: y_j = sum_{i<=j} exp(cum_j - cum_i) * (C_j.B_i) dt_i x_i
    att = jnp.einsum("bzjn,bzin->bzji", cc, bc,
                     preferred_element_type=jnp.float32)      # (B,nc,L,L)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    w = w * att[..., None]                                      # (B,nc,L,L,H)
    y_intra = jnp.einsum("bzjih,bzih,bzihp->bzjhp", w, dtc.astype(jnp.float32),
                         xc.astype(jnp.float32))

    # chunk summaries: S_z = sum_i exp(cum_last - cum_i) dt_i (B_i x x_i)
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                    # (B,nc,L,H)
    s_z = jnp.einsum("bzih,bzih,bzin,bzihp->bzhnp",
                     tail, dtc.astype(jnp.float32), bc.astype(jnp.float32),
                     xc.astype(jnp.float32))                   # (B,nc,H,N,P)

    # scan over chunks: h_z = exp(cum_last_z) h_{z-1} + S_z
    gain = jnp.exp(cum[:, :, -1, :])                           # (B,nc,H)

    def step(hprev, zs):
        g, sz = zs
        hnew = g[..., None, None] * hprev + sz
        return hnew, hprev

    init = (jnp.zeros((bb, h, n, p), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    hlast, hprevs = accounting.scan(step, init,
                                    (gain.swapaxes(0, 1), s_z.swapaxes(0, 1)))
    hprevs = hprevs.swapaxes(0, 1)                             # (B,nc,H,N,P)

    # inter-chunk: y_j += exp(cum_j) C_j . h_prev
    y_inter = jnp.einsum("bzjh,bzjn,bzhnp->bzjhp",
                         jnp.exp(cum), cc.astype(jnp.float32), hprevs)
    y = (y_intra + y_inter).reshape(bb, s, h, p)
    return y.astype(xh.dtype), hlast


def mamba2_mixer(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: Optional[Dict[str, jax.Array]] = None
                 ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,D). With `state`, runs recurrently (decode, any S>=1)."""
    bsz, s, d = x.shape
    d_inner = 2 * d
    n = cfg.ssm_state
    h = d_inner // cfg.mamba_headdim
    ph = cfg.mamba_headdim

    proj = pmm(x, p["w_in"], tag="mamba.in")
    z, xr, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xr, b, c], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv"],
                                        None if state is None else state["conv"])
    xr, b, c = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["a_log"])                                       # (H,)
    xh = xr.reshape(bsz, s, h, ph)

    if state is None:
        y, hlast = _ssd_chunked(xh, dt, a, b, c)
        new_state = None
    else:
        # recurrent path: exact scan, O(S) small steps (decode S is 1)
        def step(hprev, ins):
            xt, dtt, bt, ct = ins
            g = jnp.exp(dtt * a)                                   # (B,H)
            upd = jnp.einsum("bh,bn,bhp->bhnp", dtt, bt.astype(jnp.float32),
                             xt.astype(jnp.float32))
            hnew = g[..., None, None] * hprev + upd
            yt = jnp.einsum("bn,bhnp->bhp", ct.astype(jnp.float32), hnew)
            return hnew, yt

        hlast, ys = jax.lax.scan(
            step, state["h"].astype(jnp.float32),
            (xh.swapaxes(0, 1), dt.swapaxes(0, 1),
             b.swapaxes(0, 1), c.swapaxes(0, 1)))
        y = ys.swapaxes(0, 1).astype(x.dtype)
        new_state = {"h": hlast, "conv": conv_state}

    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, s, d_inner) * jax.nn.silu(z)
    out = pmm(y, p["w_out"], tag="mamba.out")
    if state is None:
        return out, None
    return out, new_state


def mamba2_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    d_inner = 2 * cfg.d_model
    h = d_inner // cfg.mamba_headdim
    return {
        "h": jnp.zeros((batch, h, cfg.ssm_state, cfg.mamba_headdim), jnp.float32),
        "conv": jnp.zeros((batch, 3, d_inner + 2 * cfg.ssm_state), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory)
# ---------------------------------------------------------------------------

def mlstm_params(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner = 2 * d
    ks = jax.random.split(key, 6)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_inner, cfg.dtype),
        "w_q": dense_init(ks[1], d_inner, d_inner, cfg.dtype),
        "w_k": dense_init(ks[2], d_inner, d_inner, cfg.dtype),
        "w_v": dense_init(ks[3], d_inner, d_inner, cfg.dtype),
        "w_gates": dense_init(ks[4], d, 2 * cfg.n_heads, jnp.float32),
        "w_down": dense_init(ks[5], d_inner, d, cfg.dtype),
    }


def mlstm_mixer(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Parallel (stabilized) form for training/prefill; recurrent for decode."""
    bsz, s, d = x.shape
    h = cfg.n_heads
    d_inner = 2 * d
    hd = d_inner // h

    up = pmm(x, p["w_up"], tag="mlstm.up")
    u, gate = jnp.split(up, 2, axis=-1)
    q = pmm(u, p["w_q"], tag="mlstm.q").reshape(bsz, s, h, hd)
    k = pmm(u, p["w_k"], tag="mlstm.k").reshape(bsz, s, h, hd) * hd ** -0.5
    v = pmm(u, p["w_v"], tag="mlstm.v").reshape(bsz, s, h, hd)
    gates = pmm(x.astype(jnp.float32), p["w_gates"],
                tag="mlstm.gates").reshape(bsz, s, h, 2)
    i_pre, f_pre = gates[..., 0], gates[..., 1]
    logf = jax.nn.log_sigmoid(f_pre)                        # (B,S,H)

    if state is None:
        # chunkwise-stabilized parallel form: within-chunk (L x L) decay
        # matrix + carried (C, n, m) state across chunks — the mLSTM analogue
        # of the SSD chunked scan; never materializes (S x S).
        from repro.models import accounting
        L = min(accounting.chunk(CHUNK), s)
        nc = s // L
        assert nc * L == s, f"seq {s} must divide by chunk {L}"
        qc = q.reshape(bsz, nc, L, h, hd).astype(jnp.float32)
        kc = k.reshape(bsz, nc, L, h, hd).astype(jnp.float32)
        vc = v.reshape(bsz, nc, L, h, hd).astype(jnp.float32)
        ic = i_pre.reshape(bsz, nc, L, h)
        fc = logf.reshape(bsz, nc, L, h)

        tril = jnp.tril(jnp.ones((L, L), bool))

        def chunk_step(carry, ins):
            Ch, nh, mc = carry                        # (B,H,dk,dv),(B,H,dk),(B,H)
            qz, kz, vz, iz, fz = ins                  # (B,L,H,*)
            F = jnp.cumsum(fz, axis=1)                # (B,L,H) inclusive
            # intra-chunk log-weights: F_j - F_i + i_i  (i <= j)
            dlog = F[:, :, None, :] - F[:, None, :, :] + iz[:, None, :, :]
            dlog = jnp.where(tril[None, :, :, None], dlog, -jnp.inf)
            m_intra = jnp.max(dlog, axis=2)           # (B,L,H)
            m_inter = F + mc[:, None, :]              # (B,L,H)
            m_j = jnp.maximum(m_intra, m_inter)
            w = jnp.exp(dlog - m_j[:, :, None, :])    # (B,L,L,H)
            att = jnp.einsum("bjhd,bihd->bjih", qz, kz)
            num = jnp.einsum("bjih,bjih,bihd->bjhd", att, w, vz)
            den = jnp.einsum("bjih,bjih->bjh", att, w)
            # carried-state contribution
            g_j = jnp.exp(m_inter - m_j)              # (B,L,H)
            num = num + g_j[..., None] * jnp.einsum("bjhd,bhde->bjhe", qz, Ch)
            den = den + g_j * jnp.einsum("bjhd,bhd->bjh", qz, nh)
            yz = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]
            # carry update to end of chunk
            F_L = F[:, -1, :]                         # (B,H)
            tail = F_L[:, None, :] - F + iz           # (B,L,H)
            m_new = jnp.maximum(F_L + mc, jnp.max(tail, axis=1))
            wu = jnp.exp(tail - m_new[:, None, :])
            Ch = (jnp.exp(F_L + mc - m_new)[..., None, None] * Ch
                  + jnp.einsum("bih,bihd,bihe->bhde", wu, kz, vz))
            nh = (jnp.exp(F_L + mc - m_new)[..., None] * nh
                  + jnp.einsum("bih,bihd->bhd", wu, kz))
            return (Ch, nh, m_new), yz

        Ch0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)
        nh0 = jnp.zeros((bsz, h, hd), jnp.float32)
        mc0 = jnp.full((bsz, h), -1e30, jnp.float32)
        _, ys = accounting.scan(chunk_step, (Ch0, nh0, mc0),
                                (qc.swapaxes(0, 1), kc.swapaxes(0, 1),
                                 vc.swapaxes(0, 1), ic.swapaxes(0, 1),
                                 fc.swapaxes(0, 1)))
        y = ys.swapaxes(0, 1).reshape(bsz, s, h, hd).astype(x.dtype)
        new_state = None
    else:
        def step(carry, ins):
            cm, nv, mm = carry
            qt, kt, vt, it, lft = ins
            mnew = jnp.maximum(lft + mm, it)
            fi = jnp.exp(lft + mm - mnew)
            ii = jnp.exp(it - mnew)
            cm = fi[..., None, None] * cm + ii[..., None, None] * \
                jnp.einsum("bhd,bhe->bhde", kt.astype(jnp.float32),
                           vt.astype(jnp.float32))
            nv = fi[..., None] * nv + ii[..., None] * kt.astype(jnp.float32)
            num = jnp.einsum("bhd,bhde->bhe", qt.astype(jnp.float32), cm)
            den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                                 qt.astype(jnp.float32), nv)),
                              jnp.exp(-mnew))
            return (cm, nv, mnew), num / den[..., None]

        carry = (state["c"], state["n"], state["m"])
        (cm, nv, mm), ys = jax.lax.scan(
            step, carry,
            (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
             i_pre.swapaxes(0, 1), logf.swapaxes(0, 1)))
        y = ys.swapaxes(0, 1).astype(x.dtype)
        new_state = {"c": cm, "n": nv, "m": mm}

    y = y.reshape(bsz, s, d_inner) * jax.nn.silu(gate)
    return pmm(y, p["w_down"], tag="mlstm.down"), new_state


def mlstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    h = cfg.n_heads
    hd = 2 * cfg.d_model // h
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def slstm_params(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, cfg.dtype),      # i, f, z, o
        "r": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32)
              * hd ** -0.5).astype(jnp.float32),
        "w_out": dense_init(ks[2], d, d, cfg.dtype),
    }


def slstm_mixer(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Strictly recurrent (block-diagonal recurrence) — scanned over time."""
    bsz, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    pre_all = pmm(x, p["w_in"], tag="slstm.in").reshape(
        bsz, s, h, 4 * hd).astype(jnp.float32)

    def step4(carry, pre_t):
        c, n, m, hid = carry
        rec = jnp.einsum("bhd,hde->bhe", hid, p["r"])
        it, ft, zt, ot = jnp.split(pre_t + rec, 4, axis=-1)
        mnew = jnp.maximum(ft + m, it)
        i = jnp.exp(it - mnew)
        f = jnp.exp(ft + m - mnew)
        c = f * c + i * jnp.tanh(zt)
        n = f * n + i
        hid = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, mnew, hid), hid

    if state is None:
        z = jnp.zeros((bsz, h, hd), jnp.float32)
        carry = (z, z, jnp.full((bsz, h, hd), -1e30, jnp.float32), z)
    else:
        carry = (state["c"], state["n"], state["m"], state["h"])
    carry, ys = jax.lax.scan(step4, carry, pre_all.swapaxes(0, 1))
    y = ys.swapaxes(0, 1).reshape(bsz, s, d).astype(x.dtype)
    new_state = None if state is None else {
        "c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
    return pmm(y, p["w_out"], tag="slstm.out"), new_state


def slstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    h = cfg.n_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, hd), -1e30, jnp.float32),
            "h": z}
