"""Attention blocks: GQA/MQA (with qk-norm, arbitrary head_dim), MLA
(DeepSeek-V2 latent attention with compressed KV cache), cross-attention for
encoder-decoder stacks, and the decode path against a preallocated KV cache.

The projection matmuls are the DiT-scheduled GEMMs: on the production mesh
their sharding comes from `repro.parallel.spec_rules` (the data-layout half of
the schedule), and the contraction pattern (TP all-reduce vs split-K scatter)
is the dataflow half.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, Params, apply_rope, dense_init,
                                 rms_head_norm, rope_tables)
from repro.models.matmul import pattn, pmm

NEG_INF = -1e30


def _chunk(s: int, target: int) -> int:
    """Chunk size for a length-`s` axis: the target, capped at `s`.

    The tail block is padded up to a full chunk and masked off inside
    `_flash` — chunk count stays O(s / target) for EVERY length. (The old
    rule walked down to the largest divisor of `s`, so prime or ragged
    lengths — a 4673-token VLM prefix — degraded to chunk=1 and scanned
    thousands of singleton blocks.)"""
    return max(1, min(target, s))


def gqa_params(key, cfg: ModelConfig) -> Params:
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, cfg.dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, cfg.dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, cfg.dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, cfg.dtype),
    }


def _flash_fwd(q, k, v, causal: bool, scale: float, cq: int, ck: int,
               sk_valid: int):
    """Streaming online-softmax forward. q: (b,nq,cq,hkv,g,d) fp32;
    k/v: (b,nk,ck,hkv,d|dv) fp32. Returns out (b,nq,cq,hkv,g,dv) and
    lse (b,nq,cq,hkv,g). Key positions >= `sk_valid` are tail padding
    (ragged lengths are padded to a full chunk) and masked off."""
    from repro.models import accounting
    b, nq, cq_, hkv, g, d = q.shape
    nk = k.shape[1]
    dv = v.shape[-1]

    def q_block(qi, q_blk):
        def kv_step(carry, inputs):
            m_run, l_run, acc = carry
            kj, k_blk, v_blk = inputs
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            kpos = kj * ck + jnp.arange(ck)
            if causal:
                qpos = qi * cq + jnp.arange(cq)
                mask = kpos[None, :] <= qpos[:, None]
                logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            if nk * ck > sk_valid:      # static: padding exists
                valid = kpos < sk_valid
                logits = jnp.where(valid[None, None, None, None, :],
                                   logits, NEG_INF)
            m_new = jnp.maximum(m_run, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dv), jnp.float32)
        (m, l, acc), _ = accounting.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), k.swapaxes(0, 1), v.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        # -> (b, cq, hkv, g, dv) / (b, cq, hkv, g)
        return out.transpose(0, 3, 1, 2, 4), lse.transpose(0, 3, 1, 2)

    _, (outs, lses) = accounting.scan(
        lambda c, args: (c, q_block(*args)), 0,
        (jnp.arange(nq), q.swapaxes(0, 1)))
    return outs.swapaxes(0, 1), lses.swapaxes(0, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, scale, cq, ck, sk_valid):
    out, _ = _flash_fwd(q, k, v, causal, scale, cq, ck, sk_valid)
    return out


def _flash_vjp_fwd(q, k, v, causal, scale, cq, ck, sk_valid):
    out, lse = _flash_fwd(q, k, v, causal, scale, cq, ck, sk_valid)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, scale, cq, ck, sk_valid, res, dout):
    """Flash backward: recompute p block-by-block from lse; O(S) memory."""
    from repro.models import accounting
    q, k, v, out, lse = res
    b, nq, cq_, hkv, g, d = q.shape
    nk = k.shape[1]
    dv = v.shape[-1]
    delta = (dout * out).sum(-1)                          # (b,nq,cq,hkv,g)

    def q_block(carry, inputs):
        dk_acc, dv_acc = carry
        qi, q_blk, do_blk, lse_blk, dl_blk = inputs

        def kv_step(inner, kv_inputs):
            dq_blk, dk_acc, dv_acc = inner
            kj, k_blk, v_blk = kv_inputs
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            kpos = kj * ck + jnp.arange(ck)
            if causal:
                qpos = qi * cq + jnp.arange(cq)
                mask = kpos[None, :] <= qpos[:, None]
                logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            if nk * ck > sk_valid:      # same tail mask as the forward
                valid = kpos < sk_valid
                logits = jnp.where(valid[None, None, None, None, :],
                                   logits, NEG_INF)
            p = jnp.exp(logits - lse_blk.transpose(0, 2, 3, 1)[..., None])
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_blk, v_blk)
            ds = p * (dp - dl_blk.transpose(0, 2, 3, 1)[..., None]) * scale
            dq_blk = dq_blk + jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_blk)
            dk_new = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_blk)
            dv_new = jnp.einsum("bhgqk,bqhgd->bkhd", p, do_blk)
            dk_acc = dk_acc.at[:, kj].add(dk_new)
            dv_acc = dv_acc.at[:, kj].add(dv_new)
            return (dq_blk, dk_acc, dv_acc), None

        dq0 = jnp.zeros_like(q_blk)
        (dq_blk, dk_acc, dv_acc), _ = accounting.scan(
            kv_step, (dq0, dk_acc, dv_acc),
            (jnp.arange(nk), k.swapaxes(0, 1), v.swapaxes(0, 1)))
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros_like(k)
    dv0 = jnp.zeros_like(v)
    (dk, dv_), dqs = accounting.scan(
        q_block, (dk0, dv0),
        (jnp.arange(nq), q.swapaxes(0, 1), dout.swapaxes(0, 1),
         lse.swapaxes(0, 1), delta.swapaxes(0, 1)))
    return dqs.swapaxes(0, 1), dk, dv_


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
                 chunk_q: int = 256, chunk_k: int = 256,
                 scale: Optional[float] = None) -> jax.Array:
    """Flash attention in pure jnp (custom_vjp; O(S) memory both directions):
    double scan over query/key chunks, never materializing the (Sq, Sk)
    logits. This is the memory-feasible path for 4k training and 32k prefill
    (a Pallas flash kernel plays the same role on real TPUs; this lowers
    everywhere).

    q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D).
    """
    from repro.models import accounting
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv

    cq = _chunk(sq, accounting.chunk(chunk_q))
    ck = _chunk(sk, accounting.chunk(chunk_k))
    # ragged lengths (VLM prefixes make seq lengths like 4672 = 4096 + 576
    # patches, or primes) pad the tail block up to a full chunk; padded key
    # positions are masked inside _flash, padded query rows are sliced off
    nq, nk = -(-sq // cq), -(-sk // ck)
    pad_q, pad_k = nq * cq - sq, nk * ck - sk
    if scale is None:
        scale = d ** -0.5

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    qc = qp.reshape(b, nq, cq, hkv, g, d).astype(jnp.float32)
    kc = kp.reshape(b, nk, ck, hkv, d).astype(jnp.float32)
    vc = vp.reshape(b, nk, ck, hkv, dv).astype(jnp.float32)
    out = _flash(qc, kc, vc, causal, scale, cq, ck, sk)
    return out.reshape(b, nq * cq, h, dv)[:, :sq].astype(q.dtype)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
          q_positions: Optional[jax.Array] = None,
          kv_len: Optional[jax.Array] = None,
          scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, D), k/v: (B, Sk, Hkv, D) with GQA head grouping.

    q_positions: positions of the queries (decode: the cache write index);
    kv_len: valid cache length mask bound (decode against a preallocated cache).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    groups = h // hkv
    qg = q.reshape(b, sq, hkv, groups, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits *= (d ** -0.5) if scale is None else scale
    kpos = jnp.arange(sk)
    if causal:
        qpos = q_positions if q_positions is not None else jnp.arange(sq)
        mask = kpos[None, :] <= qpos[:, None]            # (sq, sk)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if kv_len is not None:
        valid = kpos[None, :] < kv_len[:, None]          # (b, sk)
        logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


def gqa_attention(p: Params, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array,
                  cache: Optional[Dict[str, jax.Array]] = None,
                  causal: bool = True,
                  kv_input: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention. With `cache`, runs one decode step: writes
    this step's K/V at position `cache['index']` and attends to the prefix.
    kv_input: encoder output for cross-attention (no cache update then unless
    it is the first step)."""
    if cache is not None and kv_input is not None:
        # the decode branch would silently write the encoder output into the
        # self-attention cache and RoPE it — no caller means that
        raise ValueError("gqa_attention: cache and kv_input are mutually "
                         "exclusive (cached cross-attention is not "
                         "supported; precompute encoder K/V instead)")
    b, s, _ = x.shape
    hd = cfg.hd
    kv_src = kv_input if kv_input is not None else x
    q = pmm(x, p["wq"], tag="attn.q").reshape(b, s, cfg.n_heads, hd)
    k = pmm(kv_src, p["wk"], tag="attn.k").reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = pmm(kv_src, p["wv"], tag="attn.v").reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q, k = rms_head_norm(q), rms_head_norm(k)
    if kv_input is None:  # RoPE only for self-attention
        cos_q, sin_q = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_q, sin_q)

    if cache is None:
        self_causal = causal and kv_input is None
        if s > 1024 and kv_src.shape[1] > 1024:
            unfused = lambda: chunked_sdpa(q, k, v, causal=self_causal)
        else:
            unfused = lambda: _sdpa(q, k, v, causal=self_causal)
        out = pattn(q, k, v, causal=self_causal, tag="attn.sdpa",
                    unfused=unfused)
        new_cache = None
    else:
        idx = cache["index"]                              # scalar int32
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        kv_len = jnp.full((b,), idx + s, dtype=jnp.int32)
        # thread the caller's causal flag — hard-coding True here broke
        # non-causal decode (prefix-LM scoring attends to the whole prefix)
        out = pattn(q, ck, cv, causal=causal, q_positions=positions,
                    kv_len=kv_len, tag="attn.decode",
                    unfused=lambda: _sdpa(q, ck, cv, causal=causal,
                                          q_positions=positions,
                                          kv_len=kv_len))
        new_cache = {"k": ck, "v": cv, "index": idx + s}
    return pmm(out.reshape(b, s, cfg.n_heads * hd), p["wo"],
               tag="attn.o"), new_cache


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 Multi-head Latent Attention
# ---------------------------------------------------------------------------

def mla_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    p = {
        "w_dkv": dense_init(ks[0], cfg.d_model, cfg.kv_lora_rank, cfg.dtype),
        "w_kr": dense_init(ks[1], cfg.d_model, dr, cfg.dtype),
        "w_uk": dense_init(ks[2], cfg.kv_lora_rank, cfg.n_heads * dn, cfg.dtype),
        "w_uv": dense_init(ks[3], cfg.kv_lora_rank, cfg.n_heads * dn, cfg.dtype),
        "wo": dense_init(ks[4], cfg.n_heads * dn, cfg.d_model, cfg.dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], cfg.d_model, cfg.q_lora_rank, cfg.dtype)
        p["w_uq"] = dense_init(ks[6], cfg.q_lora_rank,
                               cfg.n_heads * (dn + dr), cfg.dtype)
    else:
        p["wq"] = dense_init(ks[7], cfg.d_model, cfg.n_heads * (dn + dr), cfg.dtype)
    return p


def mla_attention(p: Params, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array,
                  cache: Optional[Dict[str, jax.Array]] = None,
                  ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """MLA. Two execution forms, as in DeepSeek-V2's own deployment:

    - train/prefill (no cache): the NAIVE form — up-project K/V from c_kv and
      run flash attention at head dim (dn + dr). Projection FLOPs are
      identical to the absorbed form but scores cost (dn+dr) instead of
      (r+dr) per head — 3x cheaper for the paper config.
    - decode (cache): the ABSORBED form — W_uk folds into the query so
      attention runs in latent space against the compressed c_kv directly (an
      MQA with key dim r + dr). Only c_kv and the shared rotary key are
      cached, and no per-head K/V is ever rematerialized — the flat decode
      GEMMs of paper Insight 4."""
    b, s, _ = x.shape
    dn, dr, h, r = cfg.nope_head_dim, cfg.rope_head_dim, cfg.n_heads, cfg.kv_lora_rank

    if cfg.q_lora_rank:
        q = pmm(pmm(x, p["w_dq"], tag="mla.q_down"), p["w_uq"],
                tag="mla.q_up")
    else:
        q = pmm(x, p["wq"], tag="mla.q")
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    c_kv = pmm(x, p["w_dkv"], tag="mla.kv_down")           # (b, s, r)
    k_r = pmm(x, p["w_kr"], tag="mla.k_rope").reshape(b, s, 1, dr)

    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_r = apply_rope(k_r, cos, sin)

    if cache is not None:
        idx = cache["index"]
        c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, idx, axis=1)
        k_r = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_r, idx, axis=1)
        new_cache = {"c_kv": c_kv, "k_rope": k_r, "index": idx + s}
        kv_len = idx + s
    else:
        new_cache = None
        kv_len = None

    scale = (dn + dr) ** -0.5
    if cache is None:
        # naive form: up-project K/V once, flash attention at dim dn + dr.
        sk = c_kv.shape[1]
        k_nope = pmm(c_kv, p["w_uk"], tag="mla.k_up").reshape(b, sk, h, dn)
        v = pmm(c_kv, p["w_uv"], tag="mla.v_up").reshape(b, sk, h, dn)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_r, (b, sk, h, dr))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        if s > 1024:
            unfused = lambda: chunked_sdpa(q_full, k_full, v, causal=True,
                                           scale=scale)
        else:
            unfused = lambda: _sdpa(q_full, k_full, v, causal=True,
                                    scale=scale)
        out = pattn(q_full, k_full, v, causal=True, scale=scale,
                    tag="mla.sdpa", unfused=unfused)
        out = out.reshape(b, s, h * dn)
        return pmm(out, p["wo"], tag="mla.o"), new_cache

    # absorbed form (decode): q_lat[h] = q_nope[h] @ W_uk[h]^T  (b,s,h,r)
    # per-head batched contraction, not a single dense GEMM — stays einsum
    # but is logged so the observed workload covers the absorbed path. The
    # einsum is n_heads independent (b*s, r, dn) contractions, so count=h —
    # a single record undercounted the absorbed decode workload ~h×
    from repro.models.matmul import record_gemm
    record_gemm("mla.q_absorb", b * s, r, dn, count=h)
    w_uk = p["w_uk"].reshape(r, h, dn)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    q_aug = jnp.concatenate([q_lat, q_rope], axis=-1)      # (b,s,h,r+dr)
    k_aug = jnp.concatenate([c_kv[:, :, None, :], k_r], axis=-1)  # (b,sk,1,r+dr)
    v_lat = c_kv[:, :, None, :]                            # (b,sk,1,r)
    kv_len_b = jnp.full((b,), kv_len, jnp.int32)
    o_lat = pattn(q_aug, k_aug, v_lat, causal=True, q_positions=positions,
                  kv_len=kv_len_b, scale=scale, tag="mla.decode",
                  unfused=lambda: _sdpa(q_aug, k_aug, v_lat, causal=True,
                                        q_positions=positions,
                                        kv_len=kv_len_b, scale=scale))
    # un-absorb the values: out[h] = o_lat @ W_uv[h] — again h per-head
    # (b*s, dn, r) contractions in one einsum
    record_gemm("mla.v_unabsorb", b * s, dn, r, count=h)
    w_uv = p["w_uv"].reshape(r, h, dn)
    out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv).reshape(b, s, h * dn)
    return pmm(out, p["wo"], tag="mla.o"), new_cache


def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jax.Array]:
    """Preallocated per-layer cache pytree (decode shapes of the brief)."""
    if cfg.attn == "mla":
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.dtype),
            "k_rope": jnp.zeros((batch, max_len, 1, cfg.rope_head_dim), cfg.dtype),
            "index": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "index": jnp.zeros((), jnp.int32),
    }
