"""Top-level model assembly for all assigned architectures.

A model is a stack of homogeneous layer *groups* so parameters stack cleanly
and each group lowers as ONE `jax.lax.scan` (small HLO, fast SPMD partitioning
on the 512-device dry-run):

- dense / qk-norm / MQA / VLM archs: one group of attention blocks
- MoE archs: leading dense layers unrolled + one scanned MoE group
- zamba2: scanned super-layers of (hybrid_attn_every mamba2 blocks + one
  SHARED attention block — same weights every super-layer, as in the paper)
- xlstm: scanned super-layers of (slstm_every-1 mLSTM + 1 sLSTM)
- seamless (enc-dec): scanned encoder group + scanned decoder group with
  cross-attention to the (stub-)frontend encoder output

Entry points: init_params / forward (logits) / decode_init + decode_step
(one-token serve step against preallocated caches).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import accounting
from repro.models import shard_ctx
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.common import (ModelConfig, Params, apply_mlp, apply_norm,
                                 dense_init, mlp_params, norm_params)
from repro.models.matmul import pmm


# ---------------------------------------------------------------------------
# single blocks
# ---------------------------------------------------------------------------

def _attn_block_params(key, cfg: ModelConfig, with_mlp: bool = True,
                       cross: bool = False) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "ln1": norm_params(cfg),
        "attn": attn.mla_params(ks[0], cfg) if cfg.attn == "mla"
        else attn.gqa_params(ks[0], cfg),
    }
    if cross:
        p["ln_x"] = norm_params(cfg)
        p["xattn"] = attn.gqa_params(ks[2], cfg)
    if with_mlp:
        p["ln2"] = norm_params(cfg)
        p["mlp"] = mlp_params(ks[1], cfg)
    return p


def _attn_block(p: Params, x, cfg: ModelConfig, positions, cache=None,
                enc_out=None, causal=True):
    h = apply_norm(p["ln1"], x, cfg)
    if cfg.attn == "mla":
        a, new_cache = attn.mla_attention(p["attn"], h, cfg, positions, cache)
    else:
        a, new_cache = attn.gqa_attention(p["attn"], h, cfg, positions, cache,
                                          causal=causal)
    x = x + a
    if enc_out is not None:
        h = apply_norm(p["ln_x"], x, cfg)
        a, _ = attn.gqa_attention(p["xattn"], h, cfg, positions, None,
                                  causal=False, kv_input=enc_out)
        x = x + a
    if "mlp" in p:
        h = apply_norm(p["ln2"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    return x, new_cache


def _moe_block_params(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = _attn_block_params(k1, cfg, with_mlp=False)
    p["ln2"] = norm_params(cfg)
    p["moe"] = moe_lib.moe_params(k2, cfg)
    return p


def _moe_block(p: Params, x, cfg: ModelConfig, positions, cache=None):
    x, new_cache = _attn_block(p, x, cfg, positions, cache)
    h = apply_norm(p["ln2"], x, cfg)
    x = x + moe_lib.apply_moe(p["moe"], h, cfg)
    return x, new_cache


def _mamba_block_params(key, cfg: ModelConfig) -> Params:
    return {"ln": norm_params(cfg), "mixer": ssm.mamba2_params(key, cfg)}


def _mamba_block(p: Params, x, cfg: ModelConfig, state=None):
    h = apply_norm(p["ln"], x, cfg)
    y, new_state = ssm.mamba2_mixer(p["mixer"], h, cfg, state)
    return x + y, new_state


def _mlstm_block_params(key, cfg: ModelConfig) -> Params:
    return {"ln": norm_params(cfg), "mixer": ssm.mlstm_params(key, cfg)}


def _mlstm_block(p: Params, x, cfg: ModelConfig, state=None):
    h = apply_norm(p["ln"], x, cfg)
    y, new_state = ssm.mlstm_mixer(p["mixer"], h, cfg, state)
    return x + y, new_state


def _slstm_block_params(key, cfg: ModelConfig) -> Params:
    return {"ln": norm_params(cfg), "mixer": ssm.slstm_params(key, cfg)}


def _slstm_block(p: Params, x, cfg: ModelConfig, state=None):
    h = apply_norm(p["ln"], x, cfg)
    y, new_state = ssm.slstm_mixer(p["mixer"], h, cfg, state)
    return x + y, new_state


# ---------------------------------------------------------------------------
# layer-group structure per architecture family
# ---------------------------------------------------------------------------

def _stack(key, n: int, make_fn) -> Params:
    """Stack n block-param pytrees along a new leading axis (scan format)."""
    keys = jax.random.split(key, n)
    blocks = [make_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
                  * cfg.d_model ** -0.5).astype(cfg.dtype),
        "ln_f": norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[6], cfg.d_model, cfg.vocab, cfg.dtype)

    if cfg.frontend in ("vision_stub", "audio_stub"):
        # frontend stub: a learned projection applied to precomputed
        # patch/frame embeddings supplied by input_specs()
        p["frontend_proj"] = dense_init(ks[7], cfg.d_model, cfg.d_model, cfg.dtype)

    if cfg.is_encoder_decoder:
        p["encoder"] = _stack(ks[1], cfg.n_encoder_layers,
                              lambda k: _attn_block_params(k, cfg))
        p["decoder"] = _stack(ks[2], cfg.n_layers,
                              lambda k: _attn_block_params(k, cfg, cross=True))
        p["ln_enc"] = norm_params(cfg)
        return p

    if cfg.block_pattern == "attn":
        if cfg.n_experts:
            if cfg.n_dense_layers:
                p["dense_layers"] = _stack(ks[1], cfg.n_dense_layers,
                                           lambda k: _attn_block_params(k, cfg))
            p["moe_layers"] = _stack(ks[2], cfg.n_layers - cfg.n_dense_layers,
                                     lambda k: _moe_block_params(k, cfg))
        else:
            p["layers"] = _stack(ks[1], cfg.n_layers,
                                 lambda k: _attn_block_params(k, cfg))
    elif cfg.block_pattern == "mamba2_hybrid":
        per = cfg.hybrid_attn_every
        n_super, rem = divmod(cfg.n_layers, per)
        p["mamba_layers"] = _stack(ks[1], n_super * per,
                                   lambda k: _mamba_block_params(k, cfg))
        if rem:
            p["mamba_tail"] = _stack(ks[3], rem,
                                     lambda k: _mamba_block_params(k, cfg))
        # ONE shared attention block reused after every super-layer (zamba2)
        p["shared_attn"] = _attn_block_params(ks[2], cfg)
    elif cfg.block_pattern == "xlstm":
        per = cfg.slstm_every
        assert cfg.n_layers % per == 0, "xlstm layers must divide by slstm_every"
        n_super = cfg.n_layers // per
        p["mlstm_layers"] = _stack(ks[1], n_super * (per - 1),
                                   lambda k: _mlstm_block_params(k, cfg))
        p["slstm_layers"] = _stack(ks[2], n_super,
                                   lambda k: _slstm_block_params(k, cfg))
    else:
        raise ValueError(cfg.block_pattern)
    return p


# ---------------------------------------------------------------------------
# scanned group application
# ---------------------------------------------------------------------------

def _scan_group(stacked: Params, x, fn, remat: bool = True):
    """Run a stacked layer group as lax.scan over the leading axis (python
    loop in accounting mode so cost_analysis sees every layer)."""
    def pinned(layer_params, carry):
        return shard_ctx.constrain_tokens(fn(layer_params, carry))

    body = pinned
    if remat:
        body = jax.checkpoint(pinned)

    if accounting.UNROLL_LAYERS:
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            x = body(jax.tree.map(lambda a: a[i], stacked), x)
        return x

    def step(carry, layer_params):
        out = body(layer_params, carry)
        return out, None

    x, _ = jax.lax.scan(step, x, stacked)
    return x


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: Optional[jax.Array] = None,
            encoder_embeds: Optional[jax.Array] = None,
            remat: bool = True, return_hidden: bool = False) -> jax.Array:
    """Training/prefill forward -> logits (B, S, vocab), or the final hidden
    states (B, S, D) with return_hidden=True (the fused-CE loss path computes
    vocab projections chunk-by-chunk to avoid materializing fp32 logits).

    prefix_embeds: VLM/audio stub frontend output prepended to the sequence.
    encoder_embeds: enc-dec source-side embeddings (audio frames).
    """
    x = shard_ctx.constrain_tokens(
        jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype))
    if prefix_embeds is not None:
        pe = pmm(prefix_embeds.astype(cfg.dtype), params["frontend_proj"],
                 tag="frontend.proj")
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)

    if cfg.is_encoder_decoder:
        enc = pmm(encoder_embeds.astype(cfg.dtype), params["frontend_proj"],
                  tag="frontend.proj")
        enc = _scan_group(
            params["encoder"], enc,
            lambda p, h: _attn_block(p, h, cfg, jnp.arange(enc.shape[1]),
                                     causal=False)[0], remat)
        enc = apply_norm(params["ln_enc"], enc, cfg)
        x = _scan_group(
            params["decoder"], x,
            lambda p, h: _attn_block(p, h, cfg, positions, enc_out=enc)[0],
            remat)
    elif cfg.block_pattern == "attn":
        if cfg.n_experts:
            if cfg.n_dense_layers:
                x = _scan_group(params["dense_layers"], x,
                                lambda p, h: _attn_block(p, h, cfg, positions)[0],
                                remat)
            x = _scan_group(params["moe_layers"], x,
                            lambda p, h: _moe_block(p, h, cfg, positions)[0],
                            remat)
        else:
            x = _scan_group(params["layers"], x,
                            lambda p, h: _attn_block(p, h, cfg, positions)[0],
                            remat)
    elif cfg.block_pattern == "mamba2_hybrid":
        per = cfg.hybrid_attn_every
        n_super = jax.tree.leaves(params["mamba_layers"])[0].shape[0] // per
        # reshape stacked mamba params to (n_super, per, ...)
        grouped = jax.tree.map(
            lambda a: a.reshape(n_super, per, *a.shape[1:]),
            params["mamba_layers"])

        def super_layer(p_super, h):
            def inner(pp, hh):
                return _mamba_block(pp, hh, cfg)[0], None
            h, _ = accounting.scan(lambda c, pp: inner(pp, c), h, p_super)
            h, _ = _attn_block(params["shared_attn"], h, cfg, positions)
            return h

        x = _scan_group(grouped, x, super_layer, remat)
        if "mamba_tail" in params:
            x = _scan_group(params["mamba_tail"], x,
                            lambda p, h: _mamba_block(p, h, cfg)[0], remat)
    elif cfg.block_pattern == "xlstm":
        per = cfg.slstm_every
        n_super = jax.tree.leaves(params["slstm_layers"])[0].shape[0]
        grouped_m = jax.tree.map(
            lambda a: a.reshape(n_super, per - 1, *a.shape[1:]),
            params["mlstm_layers"])

        def super_layer(p_super, h):
            pm, psl = p_super
            h, _ = accounting.scan(lambda c, pp: (_mlstm_block(pp, c, cfg)[0], None),
                                  h, pm)
            h = _slstm_block(psl, h, cfg)[0]
            return h

        x = _scan_group((grouped_m, params["slstm_layers"]), x, super_layer, remat)
    else:
        raise ValueError(cfg.block_pattern)

    x = apply_norm(params["ln_f"], x, cfg)
    if return_hidden:
        return x
    # tied-embedding logits: x @ embed.T is the same dot_general the einsum
    # lowered to, expressed as a routable dense GEMM (lm_head_weight
    # transposes for the tied case)
    logits = pmm(x, lm_head_weight(params, cfg), tag="lm_head")
    return logits.astype(jnp.float32)


def lm_head_weight(params: Params, cfg: ModelConfig) -> jax.Array:
    """(D, vocab) projection used by the fused loss."""
    head = params.get("lm_head")
    if head is None:
        return params["embed"].astype(cfg.dtype).T
    return head


# ---------------------------------------------------------------------------
# decode (serve) path — per-layer python loop over UNSTACKED params would
# re-trace; instead we scan over layers carrying the cache pytree.
# ---------------------------------------------------------------------------

def decode_init(params: Params, cfg: ModelConfig, batch: int,
                max_len: int) -> Dict[str, Any]:
    """Preallocated cache/state pytree for one-token decode steps."""
    def stack_caches(n, make):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[make() for _ in range(n)])

    caches: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        caches["decoder"] = stack_caches(
            cfg.n_layers, lambda: attn.make_kv_cache(cfg, batch, max_len))
        return caches
    if cfg.block_pattern == "attn":
        n_dense = cfg.n_dense_layers if cfg.n_experts else 0
        if cfg.n_experts:
            if n_dense:
                caches["dense"] = stack_caches(
                    n_dense, lambda: attn.make_kv_cache(cfg, batch, max_len))
            caches["moe"] = stack_caches(
                cfg.n_layers - n_dense,
                lambda: attn.make_kv_cache(cfg, batch, max_len))
        else:
            caches["layers"] = stack_caches(
                cfg.n_layers, lambda: attn.make_kv_cache(cfg, batch, max_len))
    elif cfg.block_pattern == "mamba2_hybrid":
        per = cfg.hybrid_attn_every
        n_super, rem = divmod(cfg.n_layers, per)
        caches["mamba"] = stack_caches(n_super * per,
                                       lambda: ssm.mamba2_state(cfg, batch))
        if rem:
            caches["mamba_tail"] = stack_caches(rem,
                                                lambda: ssm.mamba2_state(cfg, batch))
        caches["shared_attn"] = stack_caches(
            n_super, lambda: attn.make_kv_cache(cfg, batch, max_len))
    elif cfg.block_pattern == "xlstm":
        per = cfg.slstm_every
        n_super = cfg.n_layers // per
        caches["mlstm"] = stack_caches(n_super * (per - 1),
                                       lambda: ssm.mlstm_state(cfg, batch))
        caches["slstm"] = stack_caches(n_super,
                                       lambda: ssm.slstm_state(cfg, batch))
    return caches


def decode_step(params: Params, caches: Dict[str, Any], tokens: jax.Array,
                position: jax.Array, cfg: ModelConfig,
                encoder_out: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step: tokens (B, 1) -> logits (B, vocab), updated caches."""
    x = shard_ctx.constrain_tokens(
        jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype))
    positions = position[None] if position.ndim == 0 else position

    def scan_layers(stacked_params, stacked_cache, x, block_fn):
        if accounting.UNROLL_LAYERS:
            n = jax.tree.leaves(stacked_params)[0].shape[0]
            new_cs = []
            for i in range(n):
                x, nc = block_fn(jax.tree.map(lambda a: a[i], stacked_params),
                                 x, jax.tree.map(lambda a: a[i], stacked_cache))
                new_cs.append(nc)
            return x, jax.tree.map(lambda *xs: jnp.stack(xs), *new_cs)

        def step(carry, pc):
            lp, lc = pc
            out, new_c = block_fn(lp, carry, lc)
            return out, new_c
        x, new_caches = jax.lax.scan(step, x, (stacked_params, stacked_cache))
        return x, new_caches

    new_caches = dict(caches)
    if cfg.is_encoder_decoder:
        x, new_caches["decoder"] = scan_layers(
            params["decoder"], caches["decoder"], x,
            lambda lp, h, lc: _attn_block(lp, h, cfg, positions, cache=lc,
                                          enc_out=encoder_out))
    elif cfg.block_pattern == "attn":
        if cfg.n_experts:
            if cfg.n_dense_layers:
                x, new_caches["dense"] = scan_layers(
                    params["dense_layers"], caches["dense"], x,
                    lambda lp, h, lc: _attn_block(lp, h, cfg, positions, cache=lc))
            x, new_caches["moe"] = scan_layers(
                params["moe_layers"], caches["moe"], x,
                lambda lp, h, lc: _moe_block(lp, h, cfg, positions, cache=lc))
        else:
            x, new_caches["layers"] = scan_layers(
                params["layers"], caches["layers"], x,
                lambda lp, h, lc: _attn_block(lp, h, cfg, positions, cache=lc))
    elif cfg.block_pattern == "mamba2_hybrid":
        per = cfg.hybrid_attn_every
        n_super = jax.tree.leaves(caches["shared_attn"])[0].shape[0]
        grouped_p = jax.tree.map(
            lambda a: a.reshape(n_super, per, *a.shape[1:]), params["mamba_layers"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape(n_super, per, *a.shape[1:]), caches["mamba"])

        def super_step(carry, pc):
            h = carry
            (pm, cm), ca = pc

            def inner(c, pcc):
                pp, cc = pcc
                out, nc = _mamba_block(pp, c, cfg, state=cc)
                return out, nc
            h, new_cm = accounting.scan(inner, h, (pm, cm))
            h, new_ca = _attn_block(params["shared_attn"], h, cfg, positions,
                                    cache=ca)
            return h, (new_cm, new_ca)

        x, (new_cm, new_ca) = accounting.scan(
            super_step, x, ((grouped_p, grouped_c), caches["shared_attn"]))
        new_caches["mamba"] = jax.tree.map(
            lambda a: a.reshape(n_super * per, *a.shape[2:]), new_cm)
        new_caches["shared_attn"] = new_ca
        if "mamba_tail" in params:
            x, new_caches["mamba_tail"] = scan_layers(
                params["mamba_tail"], caches["mamba_tail"], x,
                lambda lp, h, lc: _mamba_block(lp, h, cfg, state=lc))
    elif cfg.block_pattern == "xlstm":
        per = cfg.slstm_every
        n_super = jax.tree.leaves(caches["slstm"])[0].shape[0]
        grouped_p = jax.tree.map(
            lambda a: a.reshape(n_super, per - 1, *a.shape[1:]),
            params["mlstm_layers"])
        grouped_c = jax.tree.map(
            lambda a: a.reshape(n_super, per - 1, *a.shape[1:]), caches["mlstm"])

        def super_step(carry, pc):
            h = carry
            (pm, cm), (psl, csl) = pc

            def inner(c, pcc):
                pp, cc = pcc
                out, nc = _mlstm_block(pp, c, cfg, state=cc)
                return out, nc
            h, new_cm = accounting.scan(inner, h, (pm, cm))
            h, new_csl = _slstm_block(psl, h, cfg, state=csl)
            return h, (new_cm, new_csl)

        x, (new_cm, new_csl) = accounting.scan(
            super_step, x,
            ((grouped_p, grouped_c), (params["slstm_layers"], caches["slstm"])))
        new_caches["mlstm"] = jax.tree.map(
            lambda a: a.reshape(n_super * (per - 1), *a.shape[2:]), new_cm)
        new_caches["slstm"] = new_csl

    x = apply_norm(params["ln_f"], x, cfg)
    logits = pmm(x, lm_head_weight(params, cfg), tag="lm_head")
    return logits[:, -1].astype(jnp.float32), new_caches
