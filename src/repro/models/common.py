"""Model configuration + shared layer primitives (pure JAX, no flax).

Parameters are plain nested dicts (pytrees). Every assigned architecture is
expressible as a `ModelConfig`; block kinds cover dense attention, MLA, MoE,
Mamba2, sLSTM/mLSTM and encoder-decoder stacks. Layers are written against
`jnp` ops only so the whole stack lowers under pjit on any mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.matmul import pmm

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    act: str = "swiglu"                     # swiglu | geglu | gelu
    norm: str = "rmsnorm"                   # rmsnorm | layernorm | nonparam_ln
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    # attention family
    attn: str = "gqa"                       # gqa | mla
    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0                 # leading dense layers before MoE
    capacity_factor: float = 1.25
    # SSM / hybrid / xlstm
    block_pattern: str = "attn"             # attn | mamba2_hybrid | xlstm
    ssm_state: int = 0
    mamba_headdim: int = 64
    hybrid_attn_every: int = 6              # shared attn block period (zamba2)
    slstm_every: int = 8                    # sLSTM period in xlstm
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # modality frontend stub: prefix embeddings prepended to the sequence
    frontend: str = "none"                  # none | vision_stub | audio_stub
    n_prefix: int = 0                       # patches / frames per example
    dtype: Any = jnp.bfloat16

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Total parameters (for 6ND model-FLOPs accounting)."""
        leaves = jax.tree.leaves(jax.eval_shape(lambda: init_placeholder(self)))
        return sum(int(math.prod(l.shape)) for l in leaves)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k experts only)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        expert = 3 * self.d_model * self.moe_d_ff  # gate+up+down per expert
        n_moe_layers = self.n_layers - self.n_dense_layers
        inactive = (self.n_experts - self.moe_top_k) * expert * n_moe_layers
        return total - inactive


def init_placeholder(cfg: "ModelConfig"):
    # set lazily by model.py to avoid a circular import
    from repro.models.model import init_params
    return init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def norm_params(cfg: ModelConfig) -> Params:
    if cfg.norm == "nonparam_ln":
        return {}
    return {"scale": jnp.ones((cfg.d_model,), dtype=jnp.float32)}


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        xf = xf * p["scale"]
    elif cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
    elif cfg.norm == "nonparam_ln":  # OLMo: no learned scale/bias
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(cfg.norm)
    return xf.astype(x.dtype)


def rms_head_norm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: per-head RMS normalization (Qwen3)."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return xf.astype(x.dtype)


def rope_tables(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions: (..., dim/2)."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, dim); cos/sin: (..., seq, dim/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


def activation(cfg: ModelConfig, gate: jax.Array, up: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.act == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    if cfg.act == "gelu":
        return jax.nn.gelu(gate + up, approximate=True)
    raise ValueError(cfg.act)


def mlp_params(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, cfg.d_model, d_ff, cfg.dtype),
        "up": dense_init(k2, cfg.d_model, d_ff, cfg.dtype),
        "down": dense_init(k3, d_ff, cfg.d_model, cfg.dtype),
    }


def apply_mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = activation(cfg, pmm(x, p["gate"], tag="mlp.gate"),
                   pmm(x, p["up"], tag="mlp.up"))
    return pmm(h, p["down"], tag="mlp.down")
