"""Plan-routed matmul: the single funnel every dense model matmul goes
through, so deployment schedules — not hand-written call sites — decide how
each GEMM executes (the paper's core claim, applied to the model stack).

`pmm(x, w, tag=...)` is a drop-in replacement for `x @ w`:

- with no `GemmContext` installed it IS `x @ w` (bit-for-bit — smoke tests
  and meshless tracing are unchanged);
- with a record-only context (mesh=None) it additionally logs the
  (tag, GEMMShape) pair it would have routed, the ground truth for
  cross-validating `repro.deploy.planner.model_workload`;
- with a live mesh+planner context it flattens leading batch/seq dims to a
  2-D GEMM, consults the planner's warmed cache (exact hit, else bucketed
  transfer, else an online tune over the closed-form analytic shortlist —
  never a full tune on the dispatch path), and dispatches through
  `repro.core.gemm.dit_gemm`, which maps the tuned dataflow onto mesh
  collectives. Shapes with no usable plan still route through `dit_gemm`'s
  auto mode and are counted as fallbacks in the context stats.

The planner consult happens at trace time (GEMM shapes are static under
jit), so routing costs nothing per executed step.

Batched einsums that are not single dense GEMMs (MoE expert batches, MLA's
absorbed-form contractions) keep their einsum form but log their per-GEMM
shape via `record_gemm` so the observed workload stays complete.

See docs/architecture.md (routing path) and docs/plan-lifecycle.md (how the
plans pmm consults are produced, cached, and refined).
"""
from __future__ import annotations

import math
import time
from typing import Optional

import jax

from repro.core.schedule import AttnShape, GEMMShape
from repro.models import shard_ctx
from repro.obs import trace as obs_trace


def _gemm_shape(x: jax.Array, w: jax.Array) -> GEMMShape:
    """The 2-D problem `x @ w` solves: leading dims of x flatten into M."""
    return GEMMShape(m=int(math.prod(x.shape[:-1])), n=int(w.shape[-1]),
                     k=int(x.shape[-1]))


def _routable(x: jax.Array, w: jax.Array) -> bool:
    return (w.ndim == 2 and x.ndim >= 2 and x.shape[-1] == w.shape[0]
            and all(int(d) > 0 for d in x.shape))


def record_gemm(tag: str, m: int, n: int, k: int, count: int = 1) -> None:
    """Log a GEMM executed outside `pmm` (batched expert einsums etc.) so the
    observed workload covers everything the model runs. `count` > 1 logs one
    einsum that stands for `count` independent contractions of this shape
    (MLA's absorbed form runs one per head)."""
    ctx = shard_ctx.get_gemm_context()
    if ctx is not None and m > 0 and n > 0 and k > 0 and count > 0:
        ctx.stats.record(tag, GEMMShape(m, n, k), count=count)


def lookup_plan(planner, shape: GEMMShape):
    """Dispatch-path plan lookup:
    (plan | None, 'hit' | 'bucketed' | 'analytic' | None).

    Never runs a full tune — serving traffic must not pay a candidate search
    at trace time; cold shapes are online-tuned from the bounded analytic
    shortlist, and only a shape with no legal shortlist candidate falls back
    to the auto dataflow (counted in the stats). Classification follows the
    served plan's provenance: 'hit' = born from a full tune, 'bucketed' =
    adapted from a nearby tuned shape, 'analytic' = priced online from the
    closed-form shortlist (whether the transfer/tune happened now or on an
    earlier lookup).
    """
    plan = planner.plan_cached(shape)
    if plan is None:
        return None, None
    # literals == deploy.plan.SOURCE_BUCKETED / SOURCE_ANALYTIC (string
    # literals keep the model layer's imports free of the deploy package)
    source = getattr(plan, "source", "")
    kind = source if source in ("bucketed", "analytic") else "hit"
    return plan, kind


def _dispatch_routed(ctx, x: jax.Array, w: jax.Array, shape: GEMMShape,
                     prov: dict, tracer) -> jax.Array:
    """The routed dispatch: plan consult -> lowering -> dit_gemm.

    `prov` is the span's provenance record (also lifted into the run
    report): plan-resolve latency, hit/bucketed/fallback classification,
    plan + calibration digests, the resolved mode with its fallback-reason
    chain, and the plan's predicted cost. Digests are only computed when a
    tracer is installed — they serialize the plan, which the untraced
    dispatch path must not pay for.
    """
    from repro.core.gemm import dit_gemm   # lazy: keep import cycles at bay
    plan, kind = None, None
    if ctx.planner is not None:
        t0 = time.perf_counter()
        plan, kind = lookup_plan(ctx.planner, shape)
        resolve_us = (time.perf_counter() - t0) * 1e6
        prov["plan_resolve_us"] = round(resolve_us, 1)
        if tracer is not None:
            tracer.metrics.observe("pmm.plan_resolve_us", resolve_us)
        if kind == "hit":
            ctx.stats.hits += 1
        elif kind == "bucketed":
            ctx.stats.bucketed += 1
        elif kind == "analytic":
            ctx.stats.analytic += 1
    if plan is None:
        ctx.stats.fallback += 1
        # inner_kernel/overlap are part of the per-dispatch contract: every
        # dispatch record carries them (None/False = XLA-picked local GEMM),
        # so drift monitoring can attribute a regression to the inner level
        # without special-casing fallbacks
        prov.update(provenance="fallback", mode="auto",
                    inner_kernel=None, overlap=False)
        return dit_gemm(x, w, ctx.mesh, mode="auto", row_axis=ctx.row_axis,
                        col_axis=ctx.col_axis)
    # lower the tuned schedule here (not inside dit_gemm) so the resolved
    # mode and any fallback reasons land in the context stats — launchers
    # report WHY routing degraded, not just that it did
    from repro.core.lower import lower_schedule
    exec_plan = lower_schedule(getattr(plan, "schedule", plan), ctx.mesh,
                               ctx.row_axis, ctx.col_axis, shape=shape)
    ctx.stats.record_lowering(exec_plan)
    prov.update(provenance=kind, mode=exec_plan.mode,
                reasons=list(exec_plan.reasons()),
                inner_kernel=(exec_plan.inner_kernel.to_dict()
                              if exec_plan.inner_kernel is not None else None),
                overlap=exec_plan.overlap)
    report = getattr(plan, "report", None)
    if report is not None:
        prov["predicted_s"] = report.total_time
    if tracer is not None:
        if hasattr(plan, "digest"):
            prov["plan_digest"] = plan.digest()
        prov["calibration_digest"] = getattr(plan, "calibration_digest", "")
    return dit_gemm(x, w, ctx.mesh, row_axis=ctx.row_axis,
                    col_axis=ctx.col_axis, exec_plan=exec_plan)


def pmm(x: jax.Array, w: jax.Array, tag: str = "") -> jax.Array:
    """Plan-routed `x @ w`. x: (..., K); w: (K, N) -> (..., N)."""
    ctx = shard_ctx.get_gemm_context()
    if ctx is None:
        return x @ w
    if not _routable(x, w):
        # not a single dense GEMM this layer understands; stay out of the
        # way — but record it first, or the observed workload silently
        # undercounts whatever the model ran through here
        if (x.ndim >= 1 and w.ndim >= 2 and x.shape[-1] == w.shape[-2]
                and all(int(d) > 0 for d in x.shape)
                and all(int(d) > 0 for d in w.shape)):
            ctx.stats.record(tag, _gemm_shape(x, w))
        ctx.stats.unroutable += 1
        return x @ w
    shape = _gemm_shape(x, w)
    ctx.stats.record(tag, shape)
    tracer = obs_trace.get_tracer()
    if ctx.mesh is None:
        ctx.stats.unrouted += 1
        if tracer is not None:
            tracer.instant(f"pmm.{tag or 'untagged'}", tag=tag,
                           shape=[shape.m, shape.n, shape.k],
                           provenance="unrouted")
            tracer.metrics.counter("pmm.provenance.unrouted").inc()
        return x @ w
    if tracer is None:
        return _dispatch_routed(ctx, x, w, shape, {}, None)
    # spans measure the TRACE-TIME dispatch cost (shapes are static under
    # jit: plan consult + lowering + shard_map tracing happen once per
    # callsite per trace, never per executed step)
    t0 = time.perf_counter()
    with tracer.span(f"pmm.{tag or 'untagged'}", cat=obs_trace.CAT_PMM,
                     tag=tag, shape=[shape.m, shape.n, shape.k]) as prov:
        out = _dispatch_routed(ctx, x, w, shape, prov, tracer)
    dispatch_us = (time.perf_counter() - t0) * 1e6
    tracer.metrics.counter(f"pmm.provenance.{prov['provenance']}").inc()
    tracer.metrics.observe(
        f"pmm.dispatch_us.mode.{prov.get('mode', 'auto')}", dispatch_us)
    tracer.metrics.observe(
        f"pmm.dispatch_us.tag.{tag or 'untagged'}", dispatch_us)
    return out


# ---------------------------------------------------------------------------
# pattn: the attention funnel (pmm's shape, applied to fused attention)
# ---------------------------------------------------------------------------

def _attn_shape(q: jax.Array, k: jax.Array, v: jax.Array,
                causal: bool) -> AttnShape:
    """The attention problem the call solves: q (b, sq, h, d);
    k (b, skv, hkv, d); v (b, skv, hkv, dv)."""
    b, sq, h, d = (int(s) for s in q.shape)
    skv, hkv = int(k.shape[1]), int(k.shape[2])
    return AttnShape(b=b, sq=sq, skv=skv, h=h, hkv=hkv, d=d,
                     dv=int(v.shape[-1]), causal=bool(causal))


def _dispatch_attn(ctx, q, k, v, shape: AttnShape, causal, scale,
                   q_positions, kv_len, unfused, prov: dict, tracer):
    """Routed attention dispatch: plan consult -> lower_attention ->
    flat_attention, mirroring `_dispatch_routed` step for step. A shape
    with no plan (or one lowered to `unfused_attn`) executes the caller's
    `unfused` closure — the degrade target is always the named unfused
    path, never a silent mode switch."""
    plan, kind = None, None
    if ctx.planner is not None:
        t0 = time.perf_counter()
        plan, kind = lookup_plan(ctx.planner, shape)
        resolve_us = (time.perf_counter() - t0) * 1e6
        prov["plan_resolve_us"] = round(resolve_us, 1)
        if tracer is not None:
            tracer.metrics.observe("pattn.plan_resolve_us", resolve_us)
        if kind == "hit":
            ctx.stats.hits += 1
        elif kind == "bucketed":
            ctx.stats.bucketed += 1
        elif kind == "analytic":
            ctx.stats.analytic += 1
    if plan is None:
        ctx.stats.fallback += 1
        prov.update(provenance="fallback", mode="unfused_attn",
                    inner_kernel=None, overlap=False)
        return unfused()
    from repro.core.lower import lower_attention
    exec_plan = lower_attention(getattr(plan, "schedule", plan), ctx.mesh,
                                ctx.row_axis, ctx.col_axis, shape=shape)
    ctx.stats.record_lowering(exec_plan)
    prov.update(provenance=kind, mode=exec_plan.mode,
                reasons=list(exec_plan.reasons()),
                inner_kernel=None, overlap=False,
                attn_schedule=getattr(plan, "schedule", plan).describe())
    report = getattr(plan, "report", None)
    if report is not None:
        prov["predicted_s"] = report.total_time
    if tracer is not None:
        if hasattr(plan, "digest"):
            prov["plan_digest"] = plan.digest()
        prov["calibration_digest"] = getattr(plan, "calibration_digest", "")
    if exec_plan.mode == "unfused_attn":
        return unfused()
    from repro.core.attention import flat_attention
    return flat_attention(q, k, v, ctx.mesh, exec_plan, causal=causal,
                          scale=scale, q_positions=q_positions,
                          kv_len=kv_len)


def pattn(q: jax.Array, k: jax.Array, v: jax.Array, *, unfused,
          causal: bool = True, tag: str = "", scale=None,
          q_positions=None, kv_len=None) -> jax.Array:
    """Plan-routed attention. q: (b, sq, h, d); k/v: (b, skv, hkv, d|dv) ->
    (b, sq, h, dv). `unfused` is the zero-arg reference path the call
    degrades to when routing is off or the lowering says fused is illegal
    — every degrade is counted and carries a machine-readable reason."""
    ctx = shard_ctx.get_gemm_context()
    if ctx is None:
        return unfused()
    shape = _attn_shape(q, k, v, causal)
    ctx.stats.record_attn(tag, shape)
    tracer = obs_trace.get_tracer()
    if ctx.mesh is None:
        ctx.stats.unrouted += 1
        if tracer is not None:
            tracer.instant(f"pattn.{tag or 'untagged'}", tag=tag,
                           shape=[shape.b, shape.sq, shape.skv, shape.h,
                                  shape.hkv, shape.d, shape.dv],
                           provenance="unrouted")
            tracer.metrics.counter("pattn.provenance.unrouted").inc()
        return unfused()
    if tracer is None:
        return _dispatch_attn(ctx, q, k, v, shape, causal, scale,
                              q_positions, kv_len, unfused, {}, None)
    t0 = time.perf_counter()
    with tracer.span(f"pattn.{tag or 'untagged'}", cat=obs_trace.CAT_PMM,
                     tag=tag, shape=[shape.b, shape.sq, shape.skv, shape.h,
                                     shape.hkv, shape.d, shape.dv]) as prov:
        out = _dispatch_attn(ctx, q, k, v, shape, causal, scale,
                             q_positions, kv_len, unfused, prov, tracer)
    dispatch_us = (time.perf_counter() - t0) * 1e6
    tracer.metrics.counter(f"pattn.provenance.{prov['provenance']}").inc()
    tracer.metrics.observe(
        f"pattn.dispatch_us.mode.{prov.get('mode', 'unfused_attn')}",
        dispatch_us)
    tracer.metrics.observe(
        f"pattn.dispatch_us.tag.{tag or 'untagged'}", dispatch_us)
    return out
