"""FLOP/byte accounting mode for the roofline extraction.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count
(verified empirically — see EXPERIMENTS.md §Roofline method). The roofline
therefore measures reduced-depth configs (L = 1 and L = 2) with every scan
unrolled, and extrapolates linearly: F(L) = F(1) + (L-1) * (F(2) - F(1)).

`accounting_mode()` flips module-global switches that make the model stack
fully loop-free:
- layer groups run as python loops over stacked params (model._scan_group);
- chunked attention / SSD / mLSTM scans run with `unroll=True` and enlarged
  chunks so the unroll factor stays small;
- the sLSTM time scan cannot be unrolled (S steps); its in-loop FLOPs are
  added analytically by launch/flops.py (documented correction).
"""
from __future__ import annotations

import contextlib

UNROLL_LAYERS = False
SCAN_UNROLL = False
CHUNK_OVERRIDE = None          # chunk length for attention/SSD in accounting
MAX_UNROLL_STEPS = 8           # cap on unrolled inner-scan bodies


@contextlib.contextmanager
def accounting_mode(seq_len: int):
    global UNROLL_LAYERS, SCAN_UNROLL, CHUNK_OVERRIDE
    prev = (UNROLL_LAYERS, SCAN_UNROLL, CHUNK_OVERRIDE)
    UNROLL_LAYERS = True
    SCAN_UNROLL = True
    CHUNK_OVERRIDE = max(128, seq_len // MAX_UNROLL_STEPS)
    try:
        yield
    finally:
        UNROLL_LAYERS, SCAN_UNROLL, CHUNK_OVERRIDE = prev


def chunk(default: int) -> int:
    return CHUNK_OVERRIDE if CHUNK_OVERRIDE is not None else default


def scan(f, init, xs, length=None):
    """lax.scan that unrolls fully in accounting mode."""
    import jax
    if SCAN_UNROLL:
        return jax.lax.scan(f, init, xs, length=length, unroll=True)
    return jax.lax.scan(f, init, xs, length=length)
