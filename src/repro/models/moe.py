"""Mixture-of-Experts layer (DeepSeek-V2 / DeepSeekMoE style: fine-grained
routed experts + always-on shared experts, top-k routing).

Dispatch is capacity-bounded one-hot einsum (Switch-style) so the layer is a
pure dense-algebra SPMD program: with experts sharded over the 'model' axis
(EP), the dispatch/combine einsums lower to the all-to-all-ish collectives XLA
picks, and every expert GEMM is a regular (E_local, capacity, d) x
(E_local, d, f) batched matmul — exactly the irregular-N GEMM class the
paper's Insight 3 routes to split-K schedules. Overflowing tokens are dropped
(capacity_factor bounds the buffer, the standard trade-off).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Params, activation, dense_init, mlp_params
from repro.models.matmul import pmm, record_gemm


def moe_params(key, cfg: ModelConfig) -> Params:
    k_router, k_shared, k_experts = jax.random.split(key, 3)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(k_experts, 3)
    scale = d ** -0.5
    p = {
        "router": dense_init(k_router, d, e, jnp.float32),
        "experts": {
            "gate": (jax.random.normal(ks[0], (e, d, f), jnp.float32) * scale).astype(cfg.dtype),
            "up": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(cfg.dtype),
            "down": (jax.random.normal(ks[2], (e, f, d), jnp.float32) * (f ** -0.5)).astype(cfg.dtype),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(k_shared, cfg,
                                 d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, 4)


_GROUP_TOKENS = 512   # dispatch-group size; the einsum dispatch costs
                      # O(E * cap) = O(k * cf * GROUP_TOKENS) MACs per token,
                      # so small fixed groups keep routing overhead ~10-15% of
                      # the expert GEMMs regardless of global batch.


def _dp_groups(t: int) -> int:
    """Dispatch groups: fixed-size token groups (per-group capacity — the
    standard EP formulation computes routing positions within a local shard).
    The group count is kept a multiple of the DP shard count so the group dim
    shards cleanly over dp; the dispatch tensor is (G, TL, E, cap) — sharded
    (dp, -, EP, -) it stays small per device instead of the global-capacity
    O(T^2 k / E) blow-up."""
    from repro.models import shard_ctx
    mesh = shard_ctx.get_mesh()
    dp = 1
    if mesh is not None:
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
    if t % dp:
        dp = 1
    g = dp
    while t % (g * 2) == 0 and t // (g * 2) >= _GROUP_TOKENS:
        g *= 2
    return g


def _constrain(x: jax.Array, *spec) -> jax.Array:
    from repro.models import shard_ctx
    mesh = shard_ctx.get_mesh()
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    resolved = [dp if s == "dp" else s for s in spec]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def apply_moe(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    g = _dp_groups(t)
    tl = t // g
    cap = _capacity(tl, cfg)
    xt = _constrain(x.reshape(g, tl, d), "dp", None, None)

    gates = jax.nn.softmax(
        pmm(xt.astype(jnp.float32), p["router"], tag="moe.router"),
        axis=-1)                                                      # (G,TL,E)
    topv, topi = jax.lax.top_k(gates, k)                              # (G,TL,k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)         # renorm

    # position of each (token, choice) inside its expert's per-group buffer
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)                 # (G,TL,k,E)
    flat = onehot.reshape(g, tl * k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - 1
    pos = pos.reshape(g, tl, k, e)
    pos_tk = (pos * onehot).sum(-1)                                   # (G,TL,k)
    within = (pos_tk >= 0) & (pos_tk < cap)
    pos_c = jnp.clip(pos_tk, 0, cap - 1)

    # one-hot einsum dispatch. §Perf iteration 10 tried scatter/gather
    # dispatch instead (moves exactly T*k D-vectors, no (G,TL,E,cap) tensor):
    # REFUTED on the 512-device mesh — XLA cannot partition the scatter over
    # the expert axis and replicates the updates (deepseek-v2 train peak went
    # 152 -> 639 GB/dev, collective term 6 -> 347 s). The einsum form stays
    # SPMD-friendly because every op is dense contraction.
    within_f = within[..., None].astype(x.dtype)
    oh_cap = jax.nn.one_hot(pos_c, cap, dtype=x.dtype)                # (G,TL,k,cap)
    sel = onehot.astype(x.dtype) * within_f                           # (G,TL,k,E)
    disp = jnp.einsum("gtke,gtkc->gtec", sel, oh_cap)                 # (G,TL,E,cap)
    disp = _constrain(disp, "dp", None, "model", None)
    comb = jnp.einsum("gtke,gtkc->gtec",
                      sel.astype(jnp.float32) * topv[..., None],
                      oh_cap.astype(jnp.float32))
    comb = _constrain(comb, "dp", None, "model", None)

    # dispatch is local per (dp-group x expert-shard); expert GEMMs are
    # batched over (G, E) — sharded (dp, EP) so per-device work is 1/(dp*ep).
    # Not a single dense GEMM, so they keep the einsum form; each logical
    # (cap, d) x (d, f) problem is logged for the observed workload.
    record_gemm("moe.expert_gate", cap, p["experts"]["gate"].shape[-1], d)
    record_gemm("moe.expert_down", cap, d, p["experts"]["down"].shape[-2])
    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)                       # (G,E,cap,D)
    xe = _constrain(xe, "dp", "model", None, None)
    h = activation(cfg,
                   jnp.einsum("gecd,edf->gecf", xe, p["experts"]["gate"]),
                   jnp.einsum("gecd,edf->gecf", xe, p["experts"]["up"]))
    h = _constrain(h, "dp", "model", None, None)
    ye = jnp.einsum("gecf,efd->gecd", h, p["experts"]["down"])        # (G,E,cap,D)
    ye = _constrain(ye, "dp", "model", None, None)
    out = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), ye)
    out = _constrain(out, "dp", None, None)

    if cfg.n_shared_experts:
        sh = activation(cfg, pmm(xt, p["shared"]["gate"], tag="moe.shared_gate"),
                        pmm(xt, p["shared"]["up"], tag="moe.shared_up"))
        out = out + pmm(sh, p["shared"]["down"], tag="moe.shared_down")
    return out.reshape(b, s, d)
