from repro.hw.config import (
    AcceleratorConfig,
    HBMConfig,
    NoCConfig,
    TileConfig,
    TPUChipConfig,
    TPU_V5E,
    get_accelerator,
    softhier_a100,
    softhier_gh200,
    tpu_pod_as_accelerator,
)

__all__ = [
    "AcceleratorConfig",
    "HBMConfig",
    "NoCConfig",
    "TileConfig",
    "TPUChipConfig",
    "TPU_V5E",
    "get_accelerator",
    "softhier_a100",
    "softhier_gh200",
    "tpu_pod_as_accelerator",
]
