"""Hardware configuration for tile-based many-PE accelerators (SoftHier template)
and the TPU deployment target.

The paper (Table 1) instantiates SoftHier to match an NVIDIA GH200:
  32x32 tiles, 4096-bit NoC links, 32x2 HBM channels on west/south edges,
  per-tile matrix engine 64x16 CE array @ 1.93 TFLOPS FP8, 384 KB L1 @ 512 GB/s,
  totals: 1979 TFLOPS peak, 4 TB/s HBM.

Everything here is a plain dataclass so instances are hashable config values that
can parameterize the cost model, the simulator, and schedule legality checks.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One compute tile: matrix engine + local scratchpad."""
    # matrix engine: systolic array of ce_rows x ce_cols compute elements.
    ce_rows: int = 64
    ce_cols: int = 16
    # peak throughput of the tile's matrix engine, FLOP/s (2 flops per MAC).
    peak_flops: float = 1.93e12
    # local L1 scratchpad (software managed), bytes and bandwidth.
    l1_bytes: int = 384 * 1024
    l1_bw: float = 512e9
    # element size the engine natively computes in (fp8 in the paper's GH200 config).
    elem_bytes: int = 1
    # element dtype name the engine natively computes in. Disambiguates the
    # byte width (1 byte = fp8 here, not int8; 2 bytes = bf16 on TPU, not
    # fp16). "" means "no native preference" — pricing/lowering fall back to
    # the legacy byte-width default. Accumulation is fp32 regardless.
    elem_dtype: str = "float8_e4m3"

    @property
    def macs_per_cycle(self) -> int:
        return self.ce_rows * self.ce_cols

    @property
    def clock_hz(self) -> float:
        # peak_flops = 2 * macs_per_cycle * clock
        return self.peak_flops / (2.0 * self.macs_per_cycle)


@dataclasses.dataclass(frozen=True)
class NoCConfig:
    """Programmable network-on-chip with hardware collective support."""
    link_bits: int = 4096
    # per-link bandwidth in bytes/s; the paper gives link width, we derive
    # bytes/cycle * clock of the fabric (assume fabric clocked with tiles).
    link_bw: float = 4096 / 8 * 1e9  # 512 GB/s per link at 1 GHz
    # hardware collective primitives available (mask-based multicast/reduce).
    hw_collectives: bool = True
    # per-hop latency in cycles (used by the systolic model).
    hop_latency_cycles: int = 4


@dataclasses.dataclass(frozen=True)
class HBMConfig:
    """Distributed HBM channels along the grid edges."""
    n_channels: int = 64          # 32x2 in the paper
    channel_bw: float = 64e9      # 4 TB/s / 64 channels
    # which edges carry channels; affects NoC distance in the contention model.
    edges: Tuple[str, ...] = ("west", "south")

    @property
    def total_bw(self) -> float:
        return self.n_channels * self.channel_bw


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """A full SoftHier-template instance: grid of tiles + NoC + HBM."""
    name: str
    grid: Tuple[int, int] = (32, 32)
    tile: TileConfig = TileConfig()
    noc: NoCConfig = NoCConfig()
    hbm: HBMConfig = HBMConfig()

    @property
    def n_tiles(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def peak_flops(self) -> float:
        return self.n_tiles * self.tile.peak_flops

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte at which the roofline transitions memory->compute bound."""
        return self.peak_flops / self.hbm.total_bw


# ---------------------------------------------------------------------------
# Paper instances (Table 1 + the portability study in §4.2)
# ---------------------------------------------------------------------------

def softhier_gh200() -> AcceleratorConfig:
    """SoftHier sized to match NVIDIA GH200: 1979 TFLOPS fp8, 4 TB/s."""
    return AcceleratorConfig(
        name="softhier-gh200",
        grid=(32, 32),
        tile=TileConfig(ce_rows=64, ce_cols=16, peak_flops=1.93e12,
                        l1_bytes=384 * 1024, l1_bw=512e9, elem_bytes=1,
                        elem_dtype="float8_e4m3"),
        noc=NoCConfig(link_bits=4096, link_bw=512e9),
        hbm=HBMConfig(n_channels=64, channel_bw=64e9),
    )


def softhier_a100() -> AcceleratorConfig:
    """SoftHier sized to match NVIDIA A100: 312 TFLOPS fp16, 1.56 TB/s (§4.2)."""
    return AcceleratorConfig(
        name="softhier-a100",
        grid=(16, 16),
        tile=TileConfig(ce_rows=32, ce_cols=16, peak_flops=312e12 / 256,
                        l1_bytes=256 * 1024, l1_bw=512e9, elem_bytes=2,
                        elem_dtype="float16"),
        noc=NoCConfig(link_bits=2048, link_bw=256e9),
        hbm=HBMConfig(n_channels=32, channel_bw=1.56e12 / 32),
    )


# ---------------------------------------------------------------------------
# TPU deployment target (the machine the dry-run + roofline report against).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUChipConfig:
    """TPU v5e chip constants used for the roofline terms."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9
    ici_link_bw: float = 50e9
    # each chip has links to its mesh neighbours; 2D torus -> 4 links.
    ici_links: int = 4
    hbm_bytes: int = 16 * 1024 ** 3
    vmem_bytes: int = 128 * 1024 ** 2


TPU_V5E = TPUChipConfig()


def tpu_pod_as_accelerator(grid: Tuple[int, int] = (16, 16)) -> AcceleratorConfig:
    """View one TPU pod through the SoftHier template: chips are tiles, ICI is
    the NoC, per-chip HBM stacks are the distributed channels. Used to apply
    the paper's schedule abstraction / cost model at the inter-chip level."""
    c = TPU_V5E
    return AcceleratorConfig(
        name=f"tpu-v5e-{grid[0]}x{grid[1]}",
        grid=grid,
        tile=TileConfig(ce_rows=128, ce_cols=128, peak_flops=c.peak_flops_bf16,
                        l1_bytes=c.vmem_bytes, l1_bw=c.hbm_bw, elem_bytes=2,
                        elem_dtype="bfloat16"),
        noc=NoCConfig(link_bits=8 * int(c.ici_link_bw / 1e9), link_bw=c.ici_link_bw,
                      hw_collectives=True),
        hbm=HBMConfig(n_channels=grid[0] * grid[1], channel_bw=c.hbm_bw,
                      edges=("local",)),
    )


PRESETS = {
    "softhier-gh200": softhier_gh200,
    "softhier-a100": softhier_a100,
    "tpu-v5e-pod": tpu_pod_as_accelerator,
}


def get_accelerator(name: str) -> AcceleratorConfig:
    if name not in PRESETS:
        raise KeyError(f"unknown accelerator preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]()
