"""In-process metrics registry for the dispatch path (jax-free).

Counters and histograms the tracer (and anything else on the routing path)
accumulates into; `MetricsRegistry.to_dict()` is the snapshot the run
report embeds. Deliberately tiny and deterministic:

- counters are plain ints;
- histograms keep running count/sum/min/max plus the FIRST `max_samples`
  observations (a deterministic cap, not a random reservoir — two runs of
  the same program produce identical snapshots), from which the snapshot
  derives percentiles (p50/p95/p99 — p99 is what the serving harness's SLO
  accounting hangs its tail-latency bounds on). Observations past the cap
  still update the running stats, so count/mean/min/max stay exact.

Everything is wall-clock-agnostic: callers pass the values; the registry
never reads a clock itself.
"""
from __future__ import annotations

from typing import Dict, List


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Running stats + a deterministic first-N sample cap for percentiles."""

    __slots__ = ("count", "total", "min", "max", "samples", "max_samples")

    def __init__(self, max_samples: int = 4096) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.samples: List[float] = []
        self.max_samples = max_samples

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        if len(self.samples) < self.max_samples:
            self.samples.append(value)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the retained samples (0 <= q <= 1)."""
        if not self.samples:
            return 0.0
        ordered = sorted(self.samples)
        idx = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
        return ordered[idx]

    def to_dict(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.total,
                "mean": self.total / self.count,
                "min": self.min, "max": self.max,
                "p50": self.percentile(0.5), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class MetricsRegistry:
    """Named counters + histograms with a JSON-able snapshot."""

    def __init__(self, max_samples: int = 4096) -> None:
        self._counters: Dict[str, Counter] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._max_samples = max_samples

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(self._max_samples)
        return h

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    def to_dict(self) -> Dict[str, object]:
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._histograms.items())},
        }
