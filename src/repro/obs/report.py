"""Versioned machine-readable run reports (jax-free).

Everything the launchers used to report print-only — routing counters,
executed modes, degrade reasons, workload coverage, calibration fit
quality, drift — lands in one `run_report.json` document that CI asserts
on directly instead of scraping stdout. The human-facing prints re-render
from the SAME dict (`describe_routing`, `render_run_report`), so the two
surfaces cannot drift apart.

Schema (see docs/observability.md for the field-by-field reference):

    {
      "schema_version": 1,
      "launcher": "serve" | "train" | "dryrun",
      "routing":     GemmStats.to_dict()         (counters + modes +
                                                  degrades + observed),
      "workload":    coverage section            (optional),
      "drift":       DriftMonitor.summary()      (optional),
      "calibration": fit-quality section         (optional),
      "dispatches":  per-pmm-span provenance     (optional, from the
                                                  tracer),
      "metrics":     MetricsRegistry.to_dict()   (optional),
      "serving":     SLO/goodput section         (optional, written by
                                                  `serve --traffic`; see
                                                  docs/serving.md),
      ...extra launcher-specific keys
    }
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, List, Optional

RUN_REPORT_SCHEMA_VERSION = 1


def describe_routing(d: Dict[str, Any]) -> str:
    """The one-line routing summary, rendered from `GemmStats.to_dict()`.

    This is THE format of the launchers' `plan routing:` line —
    `GemmStats.describe()` delegates here, so the shutdown print and the
    run report are the same data by construction.
    """
    out = (f"pmm calls={d['calls']} routed={d['routed']} "
           f"(hits={d['hits']} bucketed={d['bucketed']} "
           f"analytic={d.get('analytic', 0)} "
           f"fallback={d['fallback']}) unrouted={d['unrouted']} "
           f"plan-resolve-rate={d['resolve_rate']:.0%}")
    if d.get("modes"):
        out += f" modes={dict(sorted(d['modes'].items()))}"
    if d.get("degrades") or d.get("silent_degrades"):
        out += (f" degrades={dict(sorted(d['degrades'].items()))} "
                f"silent={d['silent_degrades']}")
    return out


def dispatch_provenance(tracer) -> List[Dict[str, Any]]:
    """Per-dispatch provenance lifted from the tracer's pmm spans — the
    run report's `dispatches` section (tag, shape,
    hit/bucketed/analytic/fallback,
    plan + calibration digests, resolved mode, reasons, predicted cost)."""
    from repro.obs.trace import CAT_PMM
    return [dict(e.get("args", {}), name=e["name"])
            for e in tracer.spans(CAT_PMM)]


def build_run_report(launcher: str, *,
                     stats: Optional[Dict[str, Any]] = None,
                     workload: Optional[Dict[str, Any]] = None,
                     drift: Optional[Dict[str, Any]] = None,
                     calibration: Optional[Dict[str, Any]] = None,
                     tracer=None,
                     extra: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
    """Assemble the versioned run-report dict; None sections are omitted."""
    report: Dict[str, Any] = {
        "schema_version": RUN_REPORT_SCHEMA_VERSION,
        "launcher": launcher,
    }
    if stats is not None:
        report["routing"] = stats
    if workload is not None:
        report["workload"] = workload
    if drift is not None:
        report["drift"] = drift
    if calibration is not None:
        report["calibration"] = calibration
    if tracer is not None:
        report["dispatches"] = dispatch_provenance(tracer)
        report["metrics"] = tracer.metrics.to_dict()
    if extra:
        report.update(extra)
    return report


def write_run_report(path: str, report: Dict[str, Any]) -> str:
    """Atomically publish a run report to `path`."""
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def render_run_report(report: Dict[str, Any]) -> List[str]:
    """The human-facing shutdown lines, rendered from the report dict."""
    lines: List[str] = []
    routing = report.get("routing")
    if routing is not None:
        lines.append(f"plan routing: {describe_routing(routing)}")
        if routing.get("modes"):
            lines.append(f"lowered modes: "
                         f"{dict(sorted(routing['modes'].items()))}")
        if routing.get("degrades") or routing.get("silent_degrades"):
            lines.append(f"routing degrades (by reason): "
                         f"{dict(sorted(routing['degrades'].items()))} "
                         f"silent-auto={routing['silent_degrades']}")
    workload = report.get("workload")
    if workload is not None:
        lines.append(
            f"workload cross-validation: model_workload predicted "
            f"{workload['covered']:.0%} of the {workload['observed']} "
            f"executed GEMM shapes ({len(workload['extra'])} unpredicted, "
            f"{len(workload['missing'])} predicted-but-unexecuted)")
    serving = report.get("serving")
    if serving is not None:
        lines.append(
            f"serving [{serving['policy']}]: {serving['requests']} requests "
            f"goodput={serving['goodput_tps']:.1f} tok/s "
            f"p50={serving['p50_latency_s'] * 1e3:.1f}ms "
            f"p99={serving['p99_latency_s'] * 1e3:.1f}ms "
            f"miss={serving['deadline_miss_rate']:.0%} "
            f"cold-shapes={serving['cold_shapes']}")
    drift = report.get("drift")
    if drift is not None and drift.get("n_samples"):
        per_mode = {m: rec["geomean_ratio"]
                    for m, rec in drift["per_mode"].items()}
        stale = ("STALE — re-run calibration (dryrun --calibrate)"
                 if drift["profile_stale"] else "within threshold")
        lines.append(f"calibration drift: geomean measured/predicted="
                     f"{drift['geomean_ratio']} per-mode={per_mode} "
                     f"(threshold {drift['threshold']}: {stale})")
    return lines
