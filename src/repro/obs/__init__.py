"""Runtime observability: structured dispatch tracing, Chrome trace-event
(Perfetto) export, a metrics registry, predicted-vs-measured drift
monitoring, and versioned run reports.

The whole package is importable without jax — only the dispatch path that
*feeds* it (models/matmul.py, core/gemm.py) touches jax. A launcher
installs a `Tracer` via `set_tracer` exactly like it installs the
`GemmContext`; with no tracer installed the hooks are a global read + None
check. See docs/observability.md.
"""
from repro.obs.drift import DRIFT_STALE_THRESHOLD, DriftMonitor
from repro.obs.metrics import MetricsRegistry
from repro.obs.report import (RUN_REPORT_SCHEMA_VERSION, build_run_report,
                              describe_routing, dispatch_provenance,
                              render_run_report, write_run_report)
from repro.obs.trace import (Tracer, get_tracer, maybe_span, set_tracer,
                             tracing)

__all__ = [
    "DRIFT_STALE_THRESHOLD", "DriftMonitor", "MetricsRegistry",
    "RUN_REPORT_SCHEMA_VERSION", "build_run_report", "describe_routing",
    "dispatch_provenance", "render_run_report", "write_run_report",
    "Tracer", "get_tracer", "maybe_span", "set_tracer", "tracing",
]
