"""Structured dispatch tracer with Chrome trace-event export (jax-free).

One `Tracer` per run, installed by a launcher via `set_tracer` the same way
`shard_ctx.set_gemm_context` installs the routing context. `pmm` consults
`get_tracer()` per dispatch: with no tracer installed the dispatch path
pays one global read and a None check — cheap enough to leave the hooks in
permanently (benchmarks/tracing_bench.py asserts the bound).

Spans are *host-side trace-time* measurements: GEMM shapes are static
under jit, so a `pmm` span covers the plan consult + schedule lowering +
shard_map tracing of one callsite, not the per-step device execution
(device-side segmentation is `core.gemm`'s `jax.named_scope` wrapping —
see docs/observability.md). Each span carries the dispatch provenance
(`tag`, shape, hit/bucketed/fallback, plan + calibration digests, resolved
mode, fallback reasons, predicted cost), which is also what
`obs.report.dispatch_provenance` lifts into the run report.

Export is the Chrome trace-event JSON format, loadable directly at
https://ui.perfetto.dev: complete events (`ph: "X"`, microsecond `ts`/
`dur`) plus `ph: "M"` process-name metadata.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import time
from typing import Any, Dict, Iterator, List, Optional

from repro.obs.metrics import MetricsRegistry

# span categories used by the dispatch path ("cat" in the trace events)
CAT_PMM = "pmm"
CAT_STEP = "step"


class Tracer:
    """Collects trace events + the run's metrics; bounded, append-only."""

    def __init__(self, process_name: str = "repro",
                 max_events: int = 100_000) -> None:
        self.process_name = process_name
        self.max_events = max_events
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self.metrics = MetricsRegistry()
        self._t0_ns = time.perf_counter_ns()

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def _emit(self, event: Dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(event)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = CAT_PMM,
             **args: Any) -> Iterator[Dict[str, Any]]:
        """A complete ("X") trace event around the block.

        Yields the event's mutable `args` dict so callers can attach
        provenance discovered mid-span (resolved mode, plan digest, ...).
        """
        span_args: Dict[str, Any] = dict(args)
        t0 = self._now_us()
        try:
            yield span_args
        finally:
            dur = self._now_us() - t0
            span_args["dur_us"] = round(dur, 1)
            self._emit({"name": name, "cat": cat, "ph": "X",
                        "ts": round(t0, 1), "dur": round(dur, 1),
                        "pid": 0, "tid": 0, "args": span_args})

    def instant(self, name: str, cat: str = CAT_PMM, **args: Any) -> None:
        """A zero-duration ("i") event — markers like unrouted dispatches."""
        self._emit({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": round(self._now_us(), 1), "pid": 0, "tid": 0,
                    "args": dict(args)})

    def spans(self, cat: Optional[str] = None) -> List[Dict[str, Any]]:
        """Recorded events, optionally filtered by category."""
        if cat is None:
            return list(self.events)
        return [e for e in self.events if e.get("cat") == cat]

    def to_chrome_trace(self) -> Dict[str, Any]:
        """The Perfetto-loadable trace document (Chrome trace-event JSON)."""
        meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                 "args": {"name": self.process_name}}]
        return {"displayTimeUnit": "ms",
                "traceEvents": meta + self.events,
                "otherData": {"dropped_events": self.dropped}}

    def write(self, path: str) -> str:
        """Atomically publish the trace document to `path`."""
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_chrome_trace(), f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path


_TRACER: Optional[Tracer] = None


def set_tracer(tracer: Optional[Tracer]) -> None:
    global _TRACER
    _TRACER = tracer


def get_tracer() -> Optional[Tracer]:
    return _TRACER


@contextlib.contextmanager
def tracing(tracer: Tracer) -> Iterator[Tracer]:
    """Scoped install (tests); launchers use set_tracer directly."""
    prev = _TRACER
    set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


@contextlib.contextmanager
def maybe_span(name: str, cat: str = CAT_STEP,
               **args: Any) -> Iterator[Optional[Dict[str, Any]]]:
    """`tracer.span(...)` when a tracer is installed, else a no-op."""
    tracer = get_tracer()
    if tracer is None:
        yield None
    else:
        with tracer.span(name, cat=cat, **args) as span_args:
            yield span_args
