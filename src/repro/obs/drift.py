"""Predicted-vs-measured drift monitor (jax-free).

PR 5's calibration loop trusts a `CalibrationProfile` once, at fit time
(`fit_ok`). This module turns that one-shot gate into a continuously
checked property: an online accumulator of (predicted seconds, measured
seconds) pairs per executed mode — the same measured-vs-predicted
methodology `sim/calibrate.py` uses offline — that reports per-mode drift
ratios, their geomean, and a `profile_stale` flag when the geomean drifts
past a threshold in EITHER direction (a profile predicting 2x too fast is
exactly as stale as one predicting 2x too slow, so staleness is judged on
`max(geomean, 1/geomean)`).

Feed it fresh measurements (`dryrun --calibrate` re-running
`measure_modes`) or the persisted samples written next to the profile
(`sim.calibrate.load_samples`) — either way the prediction side comes from
`CalibrationProfile.predict` on the sample's analytical `PerfReport`, so
the monitor checks the profile actually deployed, not the raw prior.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Optional

# geomean(measured / predicted) distance from 1.0 beyond which the profile
# no longer describes the machine and should be re-fitted (dryrun
# --calibrate). 1.5 tolerates shared-host noise while catching a real
# hardware / runtime change; docs/observability.md documents the rationale.
DRIFT_STALE_THRESHOLD = 1.5


class DriftMonitor:
    """Online accumulator of measured/predicted log-ratios, per mode."""

    def __init__(self, profile=None,
                 threshold: float = DRIFT_STALE_THRESHOLD) -> None:
        if threshold < 1.0:
            raise ValueError(f"drift threshold must be >= 1.0 (a ratio "
                             f"distance), got {threshold}")
        self.profile = profile
        self.threshold = float(threshold)
        self._log_sums: Dict[str, float] = {}
        self._counts: Dict[str, int] = {}

    def add(self, mode: str, predicted_s: float, measured_s: float) -> None:
        """Record one executed-mode observation against its prediction."""
        if predicted_s <= 0.0 or measured_s <= 0.0:
            return
        self._log_sums[mode] = (self._log_sums.get(mode, 0.0)
                                + math.log(measured_s / predicted_s))
        self._counts[mode] = self._counts.get(mode, 0) + 1

    def add_samples(self, samples: Iterable) -> int:
        """Feed `CalibrationSample`s; predictions come from the monitor's
        profile (`profile.predict(sample.report)`) or, with no profile,
        from the raw analytical prior. Returns how many were added."""
        n = 0
        for s in samples:
            predicted = (self.profile.predict(s.report)
                         if self.profile is not None
                         else s.report.total_time)
            self.add(s.mode, predicted, s.measured_s)
            n += 1
        return n

    @property
    def n_samples(self) -> int:
        return sum(self._counts.values())

    def mode_ratio(self, mode: str) -> Optional[float]:
        """geomean(measured / predicted) for one mode, or None."""
        n = self._counts.get(mode, 0)
        if not n:
            return None
        return math.exp(self._log_sums[mode] / n)

    def summary(self) -> Dict[str, object]:
        """The run report's `drift` section."""
        per_mode = {
            mode: {"n": self._counts[mode],
                   "geomean_ratio": round(self.mode_ratio(mode), 4)}
            for mode in sorted(self._counts)
        }
        total = self.n_samples
        geomean = (math.exp(sum(self._log_sums.values()) / total)
                   if total else 1.0)
        distance = max(geomean, 1.0 / geomean) if geomean > 0 else math.inf
        return {
            "n_samples": total,
            "per_mode": per_mode,
            "geomean_ratio": round(geomean, 4),
            "drift_distance": round(distance, 4),
            "threshold": self.threshold,
            "profile_stale": bool(total and distance > self.threshold),
            "profile_digest": (self.profile.digest()
                               if self.profile is not None else ""),
            "profile_trusted": bool(getattr(self.profile, "fit_ok", False)),
        }
