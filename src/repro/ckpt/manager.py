"""Fault-tolerant checkpointing (DESIGN.md §5).

Atomic: a checkpoint is written to `step_N.tmp/` and renamed to `step_N/`
only when complete — a crash mid-write can never corrupt the latest
checkpoint. Sharded: each host writes only its own arrays (here: one host).
Elastic: restore() re-device_puts onto whatever mesh/shardings the new run
uses, so a checkpoint taken on one mesh shape restores onto another.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any, List[str]]:
    leaves, treedef = jax.tree.flatten(tree)
    arrs = [np.asarray(l) for l in leaves]
    dtypes = [str(a.dtype) for a in arrs]
    # numpy's npz format can't round-trip ml_dtypes (bfloat16 etc.): store
    # them as raw uint16/uint8 views and restore via the manifest dtype.
    def encode(a: np.ndarray) -> np.ndarray:
        if a.dtype.kind not in "fiub?":
            width = a.dtype.itemsize
            return a.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[width])
        return a
    return [encode(a) for a in arrs], treedef, dtypes


def _decode(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(a.dtype) != dtype_str:
        import ml_dtypes
        return a.view(np.dtype(getattr(ml_dtypes, dtype_str, dtype_str)))
    return a


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return max(steps) if steps else None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        # snapshot to host memory synchronously (consistent view), write async
        arrs, treedef, dtypes = _flatten(tree)
        meta = {"step": step, "n_arrays": len(arrs), "dtypes": dtypes,
                "treedef": str(treedef), "extra": extra or {}}

        def write():
            final = self._step_dir(step)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": a for i, a in enumerate(arrs)})
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            if self._async_thread is not None:
                self._async_thread.join()
            self._async_thread = threading.Thread(target=write, daemon=True)
            self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _gc(self) -> None:
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def restore(self, step: int, like: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of `like`; device_put with `shardings`
        if given (elastic re-mesh on load)."""
        d = self._step_dir(step)
        with open(os.path.join(d, _MANIFEST)) as f:
            meta = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        arrs = [_decode(data[f"a{i}"], meta["dtypes"][i])
                for i in range(meta["n_arrays"])]
        leaves, treedef = jax.tree.flatten(like)
        if len(leaves) != len(arrs):
            raise ValueError(f"checkpoint has {len(arrs)} arrays, "
                             f"expected {len(leaves)}")
        for got, want in zip(arrs, leaves):
            if tuple(got.shape) != tuple(want.shape):
                raise ValueError(f"shape mismatch {got.shape} vs {want.shape}")
        tree = jax.tree.unflatten(treedef, arrs)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda a, w: jax.numpy.asarray(a, dtype=w.dtype), tree, like)
        return tree

    def restore_extra(self, step: int) -> Dict:
        with open(os.path.join(self._step_dir(step), _MANIFEST)) as f:
            return json.load(f)["extra"]
