"""Fault-tolerance runtime (DESIGN.md §5): crash-resume training loop,
heartbeats, straggler mitigation, elastic re-mesh.

The loop is deliberately simple and testable on one host:
- every step's data is a pure function of the step index (repro.data), so
  resume/elastic/straggler paths never replay or desynchronize;
- checkpoints are atomic (repro.ckpt), saved every `ckpt_every` steps and on
  failure the loop restores the latest one and continues;
- a `FailureInjector` hook lets tests kill arbitrary steps to prove the
  recovery path (tests/test_fault_tolerance.py);
- heartbeats are per-host liveness files: a coordinator (or test) detects a
  silent host by mtime staleness — the signal a real cluster manager would
  use to trigger elastic down-scale, which here re-partitions the data
  pipeline via `SyntheticLM.reshard` and re-device_puts params.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM


class Heartbeat:
    def __init__(self, directory: str, host: int):
        self.path = os.path.join(directory, f"host_{host}.hb")
        os.makedirs(directory, exist_ok=True)

    def beat(self) -> None:
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    @staticmethod
    def stale_hosts(directory: str, timeout: float) -> list:
        now = time.time()
        stale = []
        for name in sorted(os.listdir(directory)):
            if name.endswith(".hb"):
                mtime = os.path.getmtime(os.path.join(directory, name))
                if now - mtime > timeout:
                    stale.append(int(name.split("_")[1].split(".")[0]))
        return stale


class FailureInjector:
    """Deterministically fail at given steps — once each (tests)."""

    def __init__(self, fail_at: Optional[set] = None):
        self.fail_at = set(fail_at or ())
        self.failed = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.failed:
            self.failed.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    max_restarts: int = 3
    heartbeat_dir: Optional[str] = None
    host: int = 0
    # straggler mitigation: if a step takes > straggler_factor x the median,
    # log it; with drop_straggler_batches the step is recomputed on fresh
    # data instead of waiting (bounded staleness).
    straggler_factor: float = 3.0
    drop_straggler_batches: bool = False


def run_training(step_fn: Callable, init_state: Any, data: SyntheticLM,
                 loop: LoopConfig,
                 make_batch_arrays: Callable[[Dict[str, np.ndarray]], Any],
                 injector: Optional[FailureInjector] = None,
                 on_metrics: Optional[Callable[[int, Dict], None]] = None
                 ) -> Any:
    """Crash-resumable loop. `step_fn(state, batch) -> (state, metrics)`.
    `init_state` must be the freshly-initialized state pytree; if a
    checkpoint exists the loop resumes from it."""
    mgr = CheckpointManager(loop.ckpt_dir)
    hb = Heartbeat(loop.heartbeat_dir, loop.host) if loop.heartbeat_dir else None

    restarts = 0
    state = init_state
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        state = mgr.restore(latest, state)
        start = latest + 1

    durations = []
    step = start
    while step < loop.total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.monotonic()
            batch = make_batch_arrays(data.batch(step))
            state, metrics = step_fn(state, batch)
            dt = time.monotonic() - t0
            durations.append(dt)
            med = float(np.median(durations[-20:]))
            if dt > loop.straggler_factor * med and len(durations) > 5:
                metrics = dict(metrics)
                metrics["straggler"] = dt / med
            if hb:
                hb.beat()
            if on_metrics:
                on_metrics(step, metrics)
            if (step + 1) % loop.ckpt_every == 0 or step + 1 == loop.total_steps:
                mgr.save(step, state, extra={"time": time.time()})
            step += 1
        except Exception:
            restarts += 1
            if restarts > loop.max_restarts:
                raise
            latest = mgr.latest_step()
            if latest is not None:
                state = mgr.restore(latest, state)
                step = latest + 1
            else:
                state = init_state
                step = 0
    return state


def elastic_reshard(params: Any, new_mesh, shardings_fn) -> Any:
    """Re-device_put a param tree onto a resized mesh (node loss/gain).
    shardings_fn(shape_tree, mesh) -> shardings tree."""
    shapes = jax.eval_shape(lambda: params)
    shardings = shardings_fn(shapes, new_mesh)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), params, shardings)
