"""DiT core: the paper's deployment-schedule abstraction, BSP IR, mask-based
collective calculus, data-layout engine, dataflow pattern builders, autotuner,
and the distributed `dit_gemm` for the TPU target."""
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.core.lower import (EXEC_MODES, ExecPlan, Fallback, MeshView,
                              lower_schedule, lowering_summary)
from repro.core.masks import (MaskSpec, TileGroup, all_group, col_group,
                              rect_group, row_group, strided_group)
from repro.core.remap import ClusterRemap, candidate_remaps, flat_mask_group
from repro.core.layout import (DataLayout, PlacementScheme, SplitScheme,
                               base_layout, candidate_layouts, optimal_layout)
from repro.core.ir import (BufferDecl, DMAOp, MMADOp, MulticastOp, P2POp,
                           Program, ReduceOp, Superstep)

__all__ = [
    "GEMMShape", "Schedule", "Tiling", "build_program",
    "EXEC_MODES", "ExecPlan", "Fallback", "MeshView", "lower_schedule",
    "lowering_summary",
    "MaskSpec", "TileGroup", "all_group", "col_group", "rect_group",
    "row_group", "strided_group",
    "ClusterRemap", "candidate_remaps", "flat_mask_group",
    "DataLayout", "PlacementScheme", "SplitScheme", "base_layout",
    "candidate_layouts", "optimal_layout",
    "BufferDecl", "DMAOp", "MMADOp", "MulticastOp", "P2POp", "Program",
    "ReduceOp", "Superstep",
]
