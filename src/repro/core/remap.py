"""Cluster index remap (paper §3.1.2).

The physical compute-tile grid is fixed (e.g. 32x32) but the optimal mapping
depends on the GEMM dimensions, so DiT reinterprets the physical grid as a
*logical* grid (1x1024, 2x512, 64x16, ...). Collectives specified on the
logical topology are automatically lowered to mask groups on the physical
grid — this module implements that lowering.

Layout convention: logical index L = lr * logical_cols + lc enumerates tiles
in *physical row-major order* (L = pi * phys_cols + pj). With power-of-2
dimensions everywhere, the bits of L split as [lr bits | lc bits] and also as
[pi bits | pj bits], so any logical row/column/rect group fixes a subset of
L's bits — which is exactly a (selector, mask) pair on (pi, pj). Hence every
logical-topology collective is ONE hardware mask collective: remap is free.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.core.masks import MaskSpec, TileGroup, axis_bits


@dataclasses.dataclass(frozen=True)
class ClusterRemap:
    """Reinterpret `physical` (rows, cols) as `logical` (rows, cols)."""
    physical: Tuple[int, int]
    logical: Tuple[int, int]

    def __post_init__(self):
        pn = self.physical[0] * self.physical[1]
        ln = self.logical[0] * self.logical[1]
        if pn != ln:
            raise ValueError(f"logical grid {self.logical} must cover the "
                             f"physical grid {self.physical} exactly ({ln} != {pn})")
        for extent in (*self.physical, *self.logical):
            if extent & (extent - 1):
                raise ValueError(f"extent {extent} must be a power of two")

    # -- index mapping ------------------------------------------------------

    def to_physical(self, lr: int, lc: int) -> Tuple[int, int]:
        flat = lr * self.logical[1] + lc
        return divmod(flat, self.physical[1])

    def to_logical(self, pi: int, pj: int) -> Tuple[int, int]:
        flat = pi * self.physical[1] + pj
        return divmod(flat, self.logical[1])

    # -- collective lowering --------------------------------------------------

    def _flat_group_to_physical(self, sel: int, mask: int) -> TileGroup:
        """A group over the flat index {L : (L & mask) == sel} as a physical
        (row, col) mask group. Bits [pj_bits) of L are pj; the rest are pi."""
        pj_bits = axis_bits(self.physical[1])
        pj_mask = (1 << pj_bits) - 1
        return TileGroup(
            row=MaskSpec(sel >> pj_bits, mask >> pj_bits),
            col=MaskSpec(sel & pj_mask, mask & pj_mask),
        )

    def logical_row_group(self, lr: int) -> TileGroup:
        """All tiles with logical row == lr, as ONE physical mask group."""
        lc_bits = axis_bits(self.logical[1])
        lr_mask = ((self.logical[0] - 1)) << lc_bits
        return self._flat_group_to_physical(lr << lc_bits, lr_mask)

    def logical_col_group(self, lc: int) -> TileGroup:
        """All tiles with logical col == lc, as ONE physical mask group."""
        lc_bits = axis_bits(self.logical[1])
        return self._flat_group_to_physical(lc, (1 << lc_bits) - 1)

    def logical_rect_group(self, lr0: int, lc0: int, h: int, w: int) -> TileGroup:
        """Aligned power-of-2 logical rectangle as ONE physical mask group."""
        if h & (h - 1) or w & (w - 1):
            raise ValueError("rect dims must be powers of two")
        if lr0 % h or lc0 % w:
            raise ValueError("rect origin must be aligned to its size")
        lc_bits = axis_bits(self.logical[1])
        sel = (lr0 << lc_bits) | lc0
        mask = (((self.logical[0] - 1) & ~(h - 1)) << lc_bits) | ((self.logical[1] - 1) & ~(w - 1))
        return self._flat_group_to_physical(sel, mask)

    def logical_members(self, group: TileGroup) -> List[Tuple[int, int]]:
        """Logical coordinates of a physical mask group's members."""
        return sorted(self.to_logical(i, j) for i, j in group.members(self.physical))


def flat_mask_group(selector: int, mask: int, physical: Tuple[int, int]) -> TileGroup:
    """A group over the row-major flat tile index, {L : (L & mask) == selector},
    expressed as a physical (row, col) mask group. Used by 3-D split-K: with
    flat = ((lm * gn) + ln) * gk + lk, every k-group / strided-broadcast group
    fixes a bit range of L, hence is ONE hardware mask collective."""
    pj_bits = axis_bits(physical[1])
    pj_mask = (1 << pj_bits) - 1
    return TileGroup(
        row=MaskSpec(selector >> pj_bits, mask >> pj_bits),
        col=MaskSpec(selector & pj_mask, mask & pj_mask),
    )


def candidate_remaps(physical: Tuple[int, int]) -> List[ClusterRemap]:
    """All power-of-2 logical reinterpretations of a physical grid — the remap
    search space the autotuner enumerates (paper Insight 4 picks 1x1024 for
    flat GEMM on a 32x32 grid)."""
    n = physical[0] * physical[1]
    remaps = []
    rows = 1
    while rows <= n:
        remaps.append(ClusterRemap(physical, (rows, n // rows)))
        rows *= 2
    return remaps
