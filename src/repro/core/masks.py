"""Mask-based collective addressing (paper §2.1).

SoftHier's NoC collectives address a *group* of tiles with a selector/mask pair
per grid dimension:

    Tile_group = { Tile_{i,j} in P | (i & M_row) == S_row  and  (j & M_col) == S_col }

A packet header carries (S_row, S_col) and (M_row, M_col); every tile whose
coordinates match joins the multicast (or contributes to the reduction).
Rows (M_row = full, M_col = 0), columns, rectangles, and power-of-2-strided
subsets are all expressible.

This module implements that calculus exactly, plus the bridge the TPU backend
needs: a power-of-2 mask over an axis of size 2^k is equivalent to *splitting*
that axis into binary sub-axes and grouping over the sub-axes whose mask bit is
0. That equivalence (proved by `tests/test_masks.py` with hypothesis) is what
lets the paper's mask groups lower onto named-mesh-axis collectives in JAX.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Selector/mask pair for one grid dimension."""
    selector: int
    mask: int

    def matches(self, coord: int) -> bool:
        return (coord & self.mask) == self.selector

    def validate(self) -> None:
        if self.selector & ~self.mask:
            raise ValueError(
                f"selector {self.selector:#x} has bits outside mask {self.mask:#x}; "
                "the group would be empty")


@dataclasses.dataclass(frozen=True)
class TileGroup:
    """A 2-D collective group = row spec x col spec (paper eq. in §2.1)."""
    row: MaskSpec
    col: MaskSpec

    def members(self, grid: Tuple[int, int]) -> List[Tuple[int, int]]:
        rows, cols = grid
        return [(i, j) for i in range(rows) for j in range(cols)
                if self.row.matches(i) and self.col.matches(j)]

    def contains(self, i: int, j: int) -> bool:
        return self.row.matches(i) and self.col.matches(j)

    def size(self, grid: Tuple[int, int]) -> int:
        return len(self.members(grid))


# -- constructors for the common patterns the paper uses --------------------

def _full_mask(extent: int) -> int:
    if extent & (extent - 1):
        raise ValueError(f"grid extent {extent} must be a power of two for mask addressing")
    return extent - 1


def row_group(i: int, grid: Tuple[int, int]) -> TileGroup:
    """All tiles in row i — the SUMMA horizontal-broadcast group."""
    return TileGroup(MaskSpec(i, _full_mask(grid[0])), MaskSpec(0, 0))


def col_group(j: int, grid: Tuple[int, int]) -> TileGroup:
    """All tiles in column j — the SUMMA vertical-broadcast group."""
    return TileGroup(MaskSpec(0, 0), MaskSpec(j, _full_mask(grid[1])))


def rect_group(i0: int, j0: int, h: int, w: int, grid: Tuple[int, int]) -> TileGroup:
    """An aligned power-of-2 rectangle with top-left corner (i0, j0).

    Used by hierarchical schedules: an inner (h x w) tile group at an aligned
    position is one mask group.
    """
    for extent, size, origin in ((grid[0], h, i0), (grid[1], w, j0)):
        if size & (size - 1):
            raise ValueError(f"rect size {size} must be a power of two")
        if origin % size:
            raise ValueError(f"rect origin {origin} must be aligned to size {size}")
    row = MaskSpec(i0, _full_mask(grid[0]) & ~(h - 1))
    col = MaskSpec(j0, _full_mask(grid[1]) & ~(w - 1))
    return TileGroup(row, col)


def strided_group(phase_i: int, stride_i: int, phase_j: int, stride_j: int,
                  grid: Tuple[int, int]) -> TileGroup:
    """Tiles {(i, j) : i % stride_i == phase_i, j % stride_j == phase_j} for
    power-of-2 strides — the 'strided broadcast' used by split-K (§3.3.2).

    i % 2^k == phase  <=>  (i & (2^k - 1)) == phase, i.e. mask = stride-1.
    """
    for stride in (stride_i, stride_j):
        if stride & (stride - 1):
            raise ValueError(f"stride {stride} must be a power of two")
    return TileGroup(MaskSpec(phase_i, stride_i - 1), MaskSpec(phase_j, stride_j - 1))


def all_group() -> TileGroup:
    """Every tile — full-grid broadcast."""
    return TileGroup(MaskSpec(0, 0), MaskSpec(0, 0))


def single(i: int, j: int, grid: Tuple[int, int]) -> TileGroup:
    return TileGroup(MaskSpec(i, _full_mask(grid[0])), MaskSpec(j, _full_mask(grid[1])))


# ---------------------------------------------------------------------------
# Mask <-> binary sub-axis equivalence (the TPU lowering bridge).
# ---------------------------------------------------------------------------

def axis_bits(extent: int) -> int:
    m = _full_mask(extent)
    return m.bit_length()


def mask_to_subaxes(spec: MaskSpec, extent: int) -> Tuple[Tuple[int, ...], int]:
    """Decompose a mask group over an axis of size 2^k into binary sub-axes.

    Viewing coordinate i as bits (b_{k-1} ... b_0), the group
    {i : (i & M) == S} fixes the bits where M is 1 (to S's bits) and leaves the
    bits where M is 0 free. Returns (free_bit_positions, fixed_value):
    the group is exactly the set of coordinates obtained by enumerating the
    free bits with the fixed bits set to `fixed_value`.

    On a named JAX mesh this means: reshape the axis into k binary sub-axes;
    the collective runs over the sub-axes at `free_bit_positions`.
    """
    spec.validate()
    k = axis_bits(extent)
    free = tuple(b for b in range(k) if not (spec.mask >> b) & 1)
    return free, spec.selector


def subaxes_to_members(free_bits: Sequence[int], fixed_value: int, extent: int) -> List[int]:
    """Enumerate the axis coordinates of a (free_bits, fixed_value) group."""
    members = []
    for n in range(1 << len(free_bits)):
        coord = fixed_value
        for idx, bit in enumerate(free_bits):
            if (n >> idx) & 1:
                coord |= 1 << bit
        if coord < extent:
            members.append(coord)
    return sorted(members)


def group_to_device_ids(group: TileGroup, grid: Tuple[int, int]) -> List[int]:
    """Flattened (row-major) device ids of a group — the form collective
    `device_groups` take in XLA."""
    return [i * grid[1] + j for (i, j) in group.members(grid)]


def partition_grid(grid: Tuple[int, int], inner: Tuple[int, int]) -> List[TileGroup]:
    """Partition the grid into aligned inner rectangles (hierarchical schedules).

    Returns the list of disjoint rect groups covering the grid; used by
    systolic-over-SUMMA / SUMMA-over-systolic to address each inner group with
    a single hardware collective.
    """
    gh, gw = grid
    ih, iw = inner
    if gh % ih or gw % iw:
        raise ValueError(f"inner {inner} must divide grid {grid}")
    groups = []
    for i0 in range(0, gh, ih):
        for j0 in range(0, gw, iw):
            groups.append(rect_group(i0, j0, ih, iw, grid))
    return groups
