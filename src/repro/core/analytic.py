"""Closed-form candidate shortlist: GOMA-style analytical-first tuning.

`autotuner.enumerate_candidates` walks the whole deployment-schedule space
and relies on pricing hundreds of candidates to find the winner — fine for
warm-up, unaffordable on a serving miss. GOMA (PAPERS.md) shows that
near-optimal GEMM mappings can be *derived* from the cost model's geometry
in microseconds instead of searched for. This module does that derivation
against the SoftHier model's resource-balance structure:

- **split-K depth** (paper Insight 3): a 2-D output grid keeps at most
  (M/ce_rows) x (N/ce_cols) engine-aligned output tiles busy. When that is
  fewer than the mesh's tiles the GEMM is flat and the idle tiles should
  take K-slices instead: the ideal depth is gk* = n_tiles / out_tiles,
  snapped to the legal power-of-two divisors, with its log-space
  neighbours (and gk = 1) kept as hedges.
- **grid aspect** (NoC/DMA balance): per superstep a (gm x gn) grid moves
  A-panels of tm*tk and B-panels of tk*tn bytes; their sum is minimized at
  gm* = sqrt(rest * M / N). The engine-alignment variant
  sqrt(rest * (M/ce_rows) / (N/ce_cols)) corrects for the asymmetric MAC
  array. The nearest legal power-of-two grids to either ideal are kept.
- **tile residency** (L1 fit): per grid, the largest K-chunk from the
  tuner's tk menu that divides K_local and fits double-buffered A/B panels
  plus the accumulator in L1 (with the fp16-accumulator fallback for flat
  cases), at the smallest macro-iteration factors that make the tiling
  divide the shape — more iterations only add supersteps and barriers
  under BSP max semantics, so the minimum feasible pair dominates.
- **dataflow choice**: split-K grids lower through `splitk_summa`;
  2-D grids enumerate `summa` / `systolic` (and the hierarchical
  compositions when the search space admits them — same trusted-
  calibration gate as the exhaustive tuner), ranked by the shared
  insight score so NoC-heavy patterns only lead where their multicast
  share pays.

`analytic_shortlist` returns the top-k Schedules of that construction
(sub-millisecond mean, no program builds); `analytic_tune` prices them
exactly like
`tune` does (same `price_candidates` loop, store-stage sweep,
calibration-aware ranking) — bounded work per plan-cache miss.
`agreement_stats` is the gate: rank agreement of the shortlist against
exhaustive search over a shape grid, exported as BENCH_analytic.json and
asserted in CI (see docs/benchmarking.md).
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.autotuner import (DATAFLOW_WEIGHT, TunedResult,
                                  default_dataflows, enumerate_candidates,
                                  insight_base, price_candidates, tune)
from repro.core.schedule import (GEMMShape, Schedule, Tiling,
                                 default_elem_dtype)
from repro.hw.config import AcceleratorConfig
from repro.sim.calibrate import is_trusted as _trusted
from repro.sim.calibrate import ranking_cost

# shortlist width: wide enough that the generator's 2-3 hedges per decision
# (split-K depths x grid aspects x tile variants) survive the cap, narrow
# enough that online pricing stays O(10) program builds.
DEFAULT_SHORTLIST_K = 32

# candidate families (split-K depth, grid, K-chunk) kept per shape: the cap
# guarantees the round-robin reaches the iteration/accumulator hedges of
# the strong families instead of spreading one-deep over every weak one.
_MAX_FAMILIES = 12

# relative band within which two priced candidates count as the same rank:
# the schedule space holds near-degenerate optima, and argmin among them is
# enumeration-order noise (mirrors the spirit of calibrate.py's
# picks_ratio <= 1 + eps trust gate).
TOP1_TIE_RTOL = 1e-3

# the tuner's K-chunk menu, largest first (larger tk = fewer pipeline fills
# and fewer supersteps, bounded by L1 residency).
_TK_MENU = (512, 256, 128, 64)

# macro-iteration factors ordered by total superstep multiplier — the first
# feasible pair wins (see module docstring).
_ITER_OPTIONS = tuple(sorted(((im, it) for im in (1, 2, 4)
                              for it in (1, 2, 4)),
                             key=lambda p: (p[0] * p[1], p)))


def _pow2_divisors(n: int) -> List[int]:
    out, v = [], 1
    while v <= n and n % v == 0:
        out.append(v)
        v *= 2
    return out


def _log2_dist(a: float, b: float) -> float:
    return abs(math.log2(max(a, 1e-12)) - math.log2(max(b, 1e-12)))


def _acc_bytes_for(tm: int, tn: int, tk_eff: int, elem_bytes: int,
                   l1_bytes: int) -> Optional[int]:
    """L1 feasibility: double-buffered A/B panels + accumulator, fp32 with
    the fp16 fallback (the same rule `enumerate_candidates` prunes by)."""
    for acc in (4, 2):
        if 2 * (tm * tk_eff + tk_eff * tn) * elem_bytes + tm * tn * acc \
                <= l1_bytes:
            return acc
    return None


def _split_k_depths(shape: GEMMShape, hw: AcceleratorConfig,
                    n_tiles: int) -> List[int]:
    """Candidate split-K depths from two closed-form signals.

    Output parallelism (Insight 3's flat-GEMM regime):
    gk* = n_tiles / ((M/ce_rows) * (N/ce_cols)) is where the 2-D grid runs
    out of engine-aligned output tiles and idle tiles should take K-slices.

    K vs tile arithmetic intensity: when K dwarfs the output dims, split-K
    trades the per-superstep NoC panel traffic for one partial-sum
    reduction — the sweet spot leaves each tile a K-slice of a handful of
    max-size engine chunks, gk = K / (tk_max * c) for small c.

    Each target is snapped to the nearest (log-space) legal power-of-two
    divisor of both the mesh and K; gk = 1 is always kept as the hedge.
    """
    legal = sorted(g for g in _pow2_divisors(n_tiles) if shape.k % g == 0)
    if not legal:
        return [1]
    out_tiles = max((shape.m / hw.tile.ce_rows)
                    * (shape.n / hw.tile.ce_cols), 1e-12)
    ideal = min(max(n_tiles / out_tiles, 1.0), float(n_tiles))
    target = 1 << max(0, round(math.log2(ideal)))
    targets = {1, max(1, target // 2), target, min(n_tiles, target * 2)}
    for chunks in (1, 2, 4):
        depth = shape.k / (_TK_MENU[0] * chunks)
        if depth >= 2:
            targets.add(1 << round(math.log2(depth)))
    picks = {min(legal, key=lambda g: (_log2_dist(g, t), g))
             for t in targets}
    return sorted(picks)


def _grid_aspects(shape: GEMMShape, hw: AcceleratorConfig, rest: int,
                  keep: int) -> List[int]:
    """The `keep` legal gm values nearest (in log space) to either ideal —
    the NoC/DMA-balance aspect sqrt(rest*M/N) (minimizes the A+B panel
    bytes each superstep moves) or its engine-aligned correction
    sqrt(rest * (M/ce_rows) / (N/ce_cols)) — plus the legal extremes
    (gm = 1 and gm = rest): a degenerate grid drops one multicast
    direction entirely, the NoC-minimizing corner a NoC-expensive
    calibration can prefer over any balanced aspect."""
    opts = [gm for gm in _pow2_divisors(rest)
            if shape.m % gm == 0 and shape.n % (rest // gm) == 0]
    if not opts:
        return []
    ideal_noc = math.sqrt(rest * shape.m / shape.n)
    ideal_eng = math.sqrt(rest * (shape.m / hw.tile.ce_rows)
                          / max(shape.n / hw.tile.ce_cols, 1e-12))

    def dist(gm: int) -> float:
        return min(_log2_dist(gm, ideal_noc), _log2_dist(gm, ideal_eng))

    picks = set(sorted(opts, key=lambda gm: (dist(gm), gm))[:keep])
    picks.update((opts[0], opts[-1]))
    return sorted(picks)


def _tile_variants(shape: GEMMShape, hw: AcceleratorConfig, gm: int, gn: int,
                   gk: int, elem_bytes: int, n_tk: int = 3
                   ) -> List[Tuple[int, int, int, int]]:
    """(iter_m, iter_n, tk_eff, acc_bytes) picks for one logical grid: the
    `n_tk` largest feasible K-chunks, each with up to three macro-iteration
    pairs — the smallest that divides the shape and fits L1 (fewest
    supersteps wins under BSP max semantics), the smallest that regains
    the fp32 accumulator when the minimum only fits fp16, and the next
    pair up as the panel-halving hedge (under a NoC-expensive calibration
    smaller multicast panels can out-price the extra supersteps)."""
    k_local = shape.k // gk
    out: List[Tuple[int, int, int, int]] = []
    seen_tk = set()
    l1 = hw.tile.l1_bytes
    db2 = 2 * elem_bytes
    # (im, it, tm+tn, tm*tn) for every pair that divides the shape — the
    # L1 check below is db2*tk*(tm+tn) + acc*tm*tn <= l1 (same rule as
    # `_acc_bytes_for`, inlined: this loop is the generation hot path).
    divisible = [(im, it,
                  shape.m // (gm * im) + shape.n // (gn * it),
                  (shape.m // (gm * im)) * (shape.n // (gn * it)))
                 for im, it in _ITER_OPTIONS
                 if not (shape.m % (gm * im) or shape.n % (gn * it))
                 and shape.m // (gm * im) and shape.n // (gn * it)]
    for tk in _TK_MENU:
        if k_local % tk and k_local > tk:
            continue
        tk_eff = min(tk, k_local)
        if tk_eff in seen_tk:
            continue
        panels = db2 * tk_eff
        feasible = [(im, it, 4 if panels * s + 4 * p <= l1 else 2)
                    for im, it, s, p in divisible
                    if panels * s + 2 * p <= l1]
        if not feasible:
            continue
        picks = [0]
        if feasible[0][2] == 2:
            fp32 = next((i for i, f in enumerate(feasible) if f[2] == 4),
                        None)
            if fp32 is not None:
                picks.append(fp32)
        nxt = max(picks) + 1
        if nxt < len(feasible):
            picks.append(nxt)
        # deep panel-halving hedge: the first pair that quarters a panel
        # dim — the far end of the supersteps-vs-panel-bytes trade.
        deep = next((i for i, f in enumerate(feasible)
                     if max(f[0], f[1]) >= 4), None)
        if deep is not None and deep not in picks:
            picks.append(deep)
        for i in sorted(set(picks)):
            im, it, acc = feasible[i]
            out.append((im, it, tk_eff, acc))
        seen_tk.add(tk_eff)
        if len(seen_tk) >= n_tk:
            break
    return out


def analytic_shortlist(shape: GEMMShape, hw: AcceleratorConfig,
                       k: int = DEFAULT_SHORTLIST_K,
                       elem_bytes: int = 1,
                       dataflows: Optional[List[str]] = None,
                       calibration=None) -> List[Schedule]:
    """Top-k closed-form Schedule shortlist for `shape` on `hw`.

    Deterministic, deduplicated, and a strict subset of the exhaustive
    candidate space (same legality rules), ranked by the shared insight
    score. The k-cap is *stratified* over (split-K depth, grid) families —
    round-robin by per-family score order — so every geometric hedge keeps
    representation; a greedy global top-k would let the prior silently
    drop whole families, which is exactly the mistake pricing exists to
    catch. The dataflow space matches `tune`'s: `dataflows` restricts it,
    and a trusted `calibration` widens the default set with the
    hierarchical compositions.
    """
    rows, cols = hw.grid
    n_tiles = rows * cols
    allowed = list(dataflows or default_dataflows(calibration))
    # family key (gk, gm, tk_eff) -> [(score, cand_key)]; Schedules
    # materialize only for the survivors (construction is the expensive
    # part). tk is part of the family key on purpose: the insight score's
    # pipeline-ceiling term systematically prefers large chunks, and a
    # global ranking would starve the small-tk hedges the DMA-bound regime
    # occasionally needs.
    families: Dict[Tuple[int, int, int], List[Tuple[float, tuple]]] = {}
    seen = set()
    base_cache: Dict[Tuple[int, int, int], float] = {}

    for gk in _split_k_depths(shape, hw, n_tiles):
        rest = n_tiles // gk
        # the exhaustive tuner's dataflow/grid compatibility rules
        dfs = [df for df in allowed if (df == "splitk_summa") == (gk > 1)]
        if not dfs:
            continue
        grids = _grid_aspects(shape, hw, rest, keep=3 if gk == 1 else 2)
        for rank, gm in enumerate(grids):
            gn = rest // gm
            for im, it, tk_eff, acc in _tile_variants(shape, hw, gm, gn, gk,
                                                      elem_bytes):
                tm, tn = shape.m // (gm * im), shape.n // (gn * it)
                base = base_cache.get((tm, tn, tk_eff))
                if base is None:
                    base = insight_base(tm, tn, tk_eff, hw)
                    base_cache[(tm, tn, tk_eff)] = base
                for df in dfs:
                    if df == "systolic" and (gm == 1 or gn == 1):
                        continue
                    if df in ("systolic_over_summa", "summa_over_systolic") \
                            and (gm % 2 or gn % 2
                                 or (shape.k // gk // tk_eff) % 2):
                        # the (2, 2) inner group must divide the logical
                        # grid AND the K-step count (each outer step
                        # consumes `inner` tk-chunks)
                        continue
                    if df == "baseline" and rank > 0:
                        # baseline is a hedge, not a contender — one grid
                        continue
                    key = (gm, gn, gk, im, it, tk_eff, df, acc)
                    if key in seen:
                        continue
                    seen.add(key)
                    families.setdefault((gk, gm, tk_eff), []).append(
                        (base * DATAFLOW_WEIGHT[df], key))

    ordered = sorted(families.values(),
                     key=lambda f: min(rec[0] for rec in f))[:_MAX_FAMILIES]
    for fam in ordered:
        fam.sort(key=lambda rec: (rec[0], rec[1]))
    picked: List[tuple] = []
    depth = 0
    while len(picked) < k and any(depth < len(f) for f in ordered):
        for fam in ordered:
            if depth < len(fam) and len(picked) < k:
                picked.append(fam[depth][1])
        depth += 1

    elem_dtype = default_elem_dtype(elem_bytes, hw)
    short = [Schedule(shape=shape,
                      tiling=Tiling(gm, gn, gk, im, it, tk_eff),
                      dataflow=df, inner=(2, 2), elem_bytes=elem_bytes,
                      acc_bytes=acc, elem_dtype=elem_dtype)
             for gm, gn, gk, im, it, tk_eff, df, acc in picked]
    if not short:
        # geometry found nothing (degenerate divisibility) — fall back to
        # the head of the exhaustive enumeration so the analytic path never
        # fails where the full search would have succeeded.
        short = list(enumerate_candidates(shape, hw, dataflows, elem_bytes,
                                          max_candidates=k,
                                          calibration=calibration))
    return short


def analytic_tune(shape: GEMMShape, hw: AcceleratorConfig,
                  dataflows: Optional[List[str]] = None,
                  elem_bytes: int = 1,
                  k: int = DEFAULT_SHORTLIST_K,
                  store_stage_options: Tuple[int, ...] = (1, 4),
                  calibration=None) -> TunedResult:
    """Price the closed-form shortlist; return the fastest schedule.

    The online-serving counterpart of `autotuner.tune`: identical pricing
    (BSP build + SoftHier estimate, store-stage sweep, calibration-aware
    ranking) over the O(k) shortlist instead of the full enumeration —
    bounded work per plan-cache miss.
    """
    short = analytic_shortlist(shape, hw, k=k, elem_bytes=elem_bytes,
                               dataflows=dataflows, calibration=calibration)
    best, log, tried = price_candidates(iter(short), hw, store_stage_options,
                                        calibration)
    if best is None:
        raise RuntimeError(
            f"no legal analytic candidate for {shape} on {hw.name}")
    return TunedResult(schedule=best[1], report=best[2],
                       candidates_tried=tried, log=log,
                       calibration=calibration.digest()
                       if _trusted(calibration) else "")


# ---------------------------------------------------------------------------
# The gate: rank agreement against exhaustive search
# ---------------------------------------------------------------------------

def agreement_stats(shapes: Sequence[GEMMShape], hw: AcceleratorConfig,
                    k: int = DEFAULT_SHORTLIST_K,
                    elem_bytes: int = 1,
                    dataflows: Optional[List[str]] = None,
                    calibration=None,
                    max_exhaustive: int = 1024,
                    store_stage_options: Tuple[int, ...] = (1, 4)
                    ) -> Dict[str, object]:
    """Rank-agreement harness: shortlist-best vs exhaustive-best per shape.

    The objective is the same `ranking_cost` both tuners minimize (the
    calibrated prediction under a trusted profile, else analytical
    seconds). `top1` means the shortlist's priced best matches — or beats,
    when `max_exhaustive` truncates the full space — the exhaustive
    optimum's cost within `TOP1_TIE_RTOL`: the candidate space holds
    near-degenerate optima (distinct schedules pricing within a fraction
    of a permille), and which one argmin lands on there is enumeration-
    order noise, not a rank disagreement. `cost_ratio` is shortlist-best /
    exhaustive-best, with no band. This is the gate BENCH_analytic.json
    exports and CI asserts on.
    """
    cost = ranking_cost(calibration)
    per_shape: List[Dict[str, object]] = []
    for shape in shapes:
        # best-of-2: generation is deterministic and pure, and the first
        # call after a multi-second exhaustive tune pays cold caches that
        # say nothing about steady-state shortlist latency.
        gen_us = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            short = analytic_shortlist(shape, hw, k=k,
                                       elem_bytes=elem_bytes,
                                       dataflows=dataflows,
                                       calibration=calibration)
            gen_us = min(gen_us, (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        best, _, tried = price_candidates(iter(short), hw,
                                          store_stage_options, calibration)
        t_short = time.perf_counter() - t0
        t1 = time.perf_counter()
        exh = tune(shape, hw, dataflows=dataflows, elem_bytes=elem_bytes,
                   max_candidates=max_exhaustive,
                   store_stage_options=store_stage_options,
                   calibration=calibration)
        t_exh = time.perf_counter() - t1
        ratio = (best[0] / cost(exh.report)) if best is not None \
            else float("inf")
        per_shape.append({
            "shape": [shape.m, shape.n, shape.k],
            "shortlist": len(short),
            "priced": tried,
            "exhaustive_priced": exh.candidates_tried,
            "gen_us": round(gen_us, 1),
            "tune_us": round(t_short * 1e6, 1),
            "exhaustive_us": round(t_exh * 1e6, 1),
            "cost_ratio": ratio,
            "top1": bool(ratio <= 1.0 + TOP1_TIE_RTOL),
        })
    n = max(len(per_shape), 1)
    ratios = [r["cost_ratio"] for r in per_shape
              if math.isfinite(r["cost_ratio"])]
    geomean = math.exp(sum(math.log(r) for r in ratios) / len(ratios)) \
        if ratios else float("inf")
    return {
        "shapes": len(per_shape),
        "k": k,
        "top1_rate": sum(r["top1"] for r in per_shape) / n,
        "max_cost_ratio": max([r["cost_ratio"] for r in per_shape],
                              default=float("inf")),
        "geomean_cost_ratio": geomean,
        "mean_shortlist": sum(r["shortlist"] for r in per_shape) / n,
        "mean_gen_us": round(sum(r["gen_us"] for r in per_shape) / n, 1),
        "max_gen_us": round(max([r["gen_us"] for r in per_shape],
                                default=0.0), 1),
        "mean_speedup_vs_exhaustive": round(
            sum(r["exhaustive_us"] for r in per_shape)
            / max(sum(r["tune_us"] for r in per_shape), 1e-9), 1),
        "per_shape": per_shape,
    }
