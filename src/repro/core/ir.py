"""BSP superstep IR (paper §3.3.3 + §2.2).

The paper specifies dataflow schedules as BSP supersteps, each containing
computation (on L1-resident data), communication (NoC or HBM), and a barrier;
the DaCe SDFG then captures per-PE data movement explicitly. Here the two are
merged into one IR: a `Program` is a list of `Superstep`s whose ops name the
exact tile, L1 buffer and double-buffer slot they touch — enough for both the
functional executor and the performance model in `repro.sim`.

BSP semantics: within a superstep, computation reads the L1 state produced by
*previous* supersteps; communication issued in a superstep becomes visible
after its barrier. Double buffering (§3.3.1) is encoded exactly the way the
paper describes — each op names the buffer slot it uses, so a superstep can
compute on slot `s` while its DMA/multicast fills slot `1 - s`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.layout import DataLayout
from repro.core.masks import TileGroup

Coord = Tuple[int, int]

# Canonical element-dtype name -> byte width for every dtype a deployment can
# declare. The byte-keyed legacy map (`core.schedule.DTYPE_OF_BYTES`)
# conflates elem_bytes=1 with int8 and 2 with float16; this name-keyed map is
# the authoritative direction — fp8 (float8_e4m3, the GH200 preset's engine
# dtype) and bfloat16 price and lower under their real names. numpy cannot
# parse "float8_e4m3"/"bfloat16" without ml_dtypes, so every byte-width
# lookup on dtype *names* must go through here first.
ELEM_BYTES_OF_DTYPE = {
    "int8": 1,
    "float8_e4m3": 1,
    "float16": 2,
    "bfloat16": 2,
    "float32": 4,
}


# ---------------------------------------------------------------------------
# Ops
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DMAOp:
    """HBM <-> L1 transfer executed by one tile's DMA engine."""
    tile: Coord
    kind: str                   # 'load' | 'store'
    matrix: str                 # 'A' | 'B' | 'C'
    tile_coord: Coord           # (ti, tj) tile index within the matrix
    buf: str                    # destination/source L1 buffer name
    slot: int = 0               # double-buffer slot
    accumulate: bool = False    # store with += (split-K commit)


@dataclasses.dataclass(frozen=True)
class MulticastOp:
    """Hardware NoC multicast: src tile's L1 buffer -> every group member."""
    src: Coord
    group: TileGroup
    buf: str                    # buffer name (same on src and destinations)
    slot: int = 0
    dst_buf: Optional[str] = None   # defaults to buf
    dst_slot: Optional[int] = None  # defaults to slot
    # the multicast consumes data DMA'd in the SAME superstep (owner fetch ->
    # fabric multicast chaining); the cost model serializes DMA + NoC then.
    after_dma: bool = False


@dataclasses.dataclass(frozen=True)
class ReduceOp:
    """Hardware NoC reduction: sum of group members' buffers -> dst tile."""
    group: TileGroup
    dst: Coord
    buf: str
    slot: int = 0
    dst_buf: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class P2POp:
    """Nearest-neighbour send (systolic propagation). src == dst is a local
    L1-to-L1 copy (slice feed), priced at L1 bandwidth by the cost model."""
    src: Coord
    dst: Coord
    buf: str
    slot: int = 0
    dst_slot: Optional[int] = None  # defaults to slot
    dst_buf: Optional[str] = None   # defaults to buf


@dataclasses.dataclass(frozen=True)
class MMADOp:
    """Matrix-multiply-add on one tile's matrix engine: acc += a @ b."""
    tile: Coord
    a_buf: str
    a_slot: int
    b_buf: str
    b_slot: int
    acc_buf: str = "C"
    acc_slot: int = 0
    init: bool = False          # first k-step: overwrite the accumulator
    # logical tile dims, for the cost model (may differ per op in ragged cases)
    tm: int = 0
    tn: int = 0
    tk: int = 0


CommOp = (DMAOp, MulticastOp, ReduceOp, P2POp)


@dataclasses.dataclass
class Superstep:
    """One BSP superstep: compute || communicate, then barrier."""
    compute: List[MMADOp] = dataclasses.field(default_factory=list)
    comm: List[object] = dataclasses.field(default_factory=list)
    label: str = ""


@dataclasses.dataclass
class BufferDecl:
    """L1 buffer declaration: `slots` copies of `shape` in every tile."""
    name: str
    shape: Tuple[int, int]
    slots: int = 1
    dtype: str = "float32"

    @property
    def bytes_per_slot(self) -> int:
        eb = ELEM_BYTES_OF_DTYPE.get(self.dtype)
        if eb is None:
            import numpy as np
            eb = np.dtype(self.dtype).itemsize
        return int(self.shape[0] * self.shape[1] * eb)


@dataclasses.dataclass
class Program:
    """A complete deployment: metadata + L1 buffer plan + supersteps."""
    grid: Coord                             # physical tile grid
    shape: Tuple[int, int, int]             # GEMM (M, N, K)
    tile_shape: Tuple[int, int, int]        # (TM, TN, TK)
    buffers: Dict[str, BufferDecl]
    layouts: Dict[str, DataLayout]          # per matrix 'A' | 'B' | 'C'
    supersteps: List[Superstep] = dataclasses.field(default_factory=list)
    double_buffer: bool = True
    name: str = ""
    elem_bytes: int = 4          # deployment element size (A/B operands, C commit)

    def add(self, step: Superstep) -> None:
        self.supersteps.append(step)

    # -- sanity checks -------------------------------------------------------

    def l1_bytes_per_tile(self) -> int:
        return sum(b.bytes_per_slot * b.slots for b in self.buffers.values())

    def validate(self, l1_capacity: Optional[int] = None) -> None:
        rows, cols = self.grid
        for step in self.supersteps:
            for op in step.compute:
                if not (0 <= op.tile[0] < rows and 0 <= op.tile[1] < cols):
                    raise ValueError(f"MMAD on out-of-grid tile {op.tile}")
                for buf in (op.a_buf, op.b_buf, op.acc_buf):
                    if buf not in self.buffers:
                        raise ValueError(f"MMAD references undeclared buffer {buf!r}")
            for op in step.comm:
                if isinstance(op, DMAOp) and op.buf not in self.buffers:
                    raise ValueError(f"DMA references undeclared buffer {op.buf!r}")
                if isinstance(op, MulticastOp) and op.buf not in self.buffers:
                    raise ValueError(f"multicast references undeclared buffer {op.buf!r}")
        if l1_capacity is not None:
            used = self.l1_bytes_per_tile()
            if used > l1_capacity:
                raise ValueError(
                    f"L1 plan uses {used} bytes/tile > capacity {l1_capacity} "
                    f"(buffers: { {k: (v.shape, v.slots) for k, v in self.buffers.items()} })")

    # -- statistics (used by tests and the cost model) ------------------------

    def op_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {"mmad": 0, "dma_load": 0, "dma_store": 0,
                                  "multicast": 0, "reduce": 0, "p2p": 0}
        for step in self.supersteps:
            counts["mmad"] += len(step.compute)
            for op in step.comm:
                if isinstance(op, DMAOp):
                    counts["dma_load" if op.kind == "load" else "dma_store"] += 1
                elif isinstance(op, MulticastOp):
                    counts["multicast"] += 1
                elif isinstance(op, ReduceOp):
                    counts["reduce"] += 1
                elif isinstance(op, P2POp):
                    counts["p2p"] += 1
        return counts

    def total_flops(self) -> int:
        return sum(2 * op.tm * op.tn * op.tk
                   for step in self.supersteps for op in step.compute)

    def hbm_bytes(self, elem_bytes: int = 4) -> int:
        """Total HBM traffic (loads + stores) implied by the program."""
        tm, tn, tk = self.tile_shape
        sizes = {"A": tm * tk, "B": tk * tn, "C": tm * tn}
        total = 0
        for step in self.supersteps:
            for op in step.comm:
                if isinstance(op, DMAOp):
                    total += sizes[op.matrix] * elem_bytes
        return total
