"""Deployment-schedule abstraction (paper §3).

A `Schedule` is the complete, parameterizable description DiT generates code
from: (1) tiling & mapping — how the GEMM is decomposed over the logical tile
grid, including 3-D split-K and cluster index remap; (2) data layout — split +
placement schemes per matrix; (3) dataflow — which pattern primitive moves the
data (baseline / SUMMA / systolic / hierarchical / split-K) and its knobs
(double buffering, store pipeline stages).

`build_program(schedule, hw)` dispatches to the dataflow builders and returns
the BSP `Program` that the simulator executes and the cost model prices.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core import layout as layout_lib
from repro.core.ir import ELEM_BYTES_OF_DTYPE, Program
from repro.core.remap import ClusterRemap
from repro.hw.config import AcceleratorConfig

# Dispatch-time working-set budget for an inner kernel (bytes). A v5e has
# ~128 MB VMEM but Pallas double-buffers every operand block, so the planner
# and `kernels/ops.pick_block_shape` share this much tighter cap; lowering
# demotes (reason `inner_kernel_too_large`) any persisted kernel that
# exceeds it instead of letting the dispatch OOM VMEM.
INNER_VMEM_BUDGET = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class GEMMShape:
    m: int
    n: int
    k: int

    def flops(self) -> int:
        return 2 * self.m * self.n * self.k

    def min_bytes(self, elem_bytes: int = 4) -> int:
        """Compulsory HBM traffic: read A and B once, write C once."""
        return elem_bytes * (self.m * self.k + self.k * self.n + self.m * self.n)

    def intensity(self, elem_bytes: int = 4) -> float:
        return self.flops() / self.min_bytes(elem_bytes)


@dataclasses.dataclass(frozen=True)
class AttnShape:
    """One fused-attention composition problem (FlatAttention).

    Unlike a GEMM, attention has no single (m, n, k): the QKᵀ and PV
    contractions share the KV sequence axis and are glued by the online
    softmax, so the planner keys attention work on the full geometry.
    Separate `d` (QK head dim) and `dv` (V head dim) cover MLA's absorbed
    decode, whose keys are rank+rope wide but whose values are rank wide.
    Frozen + hashable so it can serve as a plan-cache key exactly like
    `GEMMShape`.
    """
    b: int              # batch
    sq: int             # query sequence length (decode: 1 per step)
    skv: int            # key/value sequence length (decode: cache capacity)
    h: int              # query heads
    hkv: int            # KV heads (GQA groups; 1 = MQA / MLA-absorbed)
    d: int              # QK head dim
    dv: int             # V head dim
    causal: bool = True

    def flops(self) -> int:
        """QKᵀ (2·b·h·sq·skv·d) + PV (2·b·h·sq·skv·dv)."""
        return 2 * self.b * self.h * self.sq * self.skv * (self.d + self.dv)

    def min_bytes(self, elem_bytes: int = 4) -> int:
        """Compulsory HBM traffic: read Q and KV once, write O once."""
        q = self.b * self.sq * self.h * self.d
        kv = self.b * self.skv * self.hkv * (self.d + self.dv)
        o = self.b * self.sq * self.h * self.dv
        return elem_bytes * (q + kv + o)

    def intensity(self, elem_bytes: int = 4) -> float:
        return self.flops() / self.min_bytes(elem_bytes)

    def describe(self) -> str:
        c = "causal" if self.causal else "full"
        return (f"attn[b{self.b} q{self.sq} kv{self.skv} "
                f"h{self.h}/{self.hkv} d{self.d}v{self.dv} {c}]")


# The fused attention dataflow name. Deliberately NOT in `DATAFLOWS`: every
# name there has a BSP `build_program` builder, while flat attention lowers
# through `lower_attention` to its own exec modes and is priced by
# `sim.perf.estimate_attention`.
ATTN_DATAFLOW = "flat_attention"

# collective compositions the fused dataflow can run as (docs/dataflows.md):
#   merge — KV row-sharded, every device scans its local KV, one final
#           pmax/psum combine of (m, l, acc) partials across the row axis;
#   ring  — Q additionally row-sharded over sq, KV blocks rotate around a
#           `ppermute` ring so each device sees the full KV stream.
ATTN_COMPOSITIONS = ("merge", "ring")


@dataclasses.dataclass(frozen=True)
class AttnSchedule:
    """One point in the fused-attention deployment space.

    The candidate space is tiny compared to GEMMs — composition × KV chunk —
    because the head/batch mapping is dictated by the mesh (head sharding is
    a lowering legality question, not a tunable). `kv_chunk` is the KV tile
    one superstep streams through L1 (larger amortizes softmax passes and
    barriers, smaller fits the working set).
    """
    shape: AttnShape
    composition: str = "merge"
    kv_chunk: int = 256
    dataflow: str = ATTN_DATAFLOW
    elem_bytes: int = 4
    elem_dtype: str = ""
    # parity with Schedule's dispatch contract (pattn provenance rows carry
    # inner_kernel/overlap keys like pmm's; attention has no inner kernel)
    inner_kernel: Optional[InnerKernel] = None
    overlap: bool = False

    def describe(self) -> str:
        return (f"{self.dataflow}/{self.composition}"
                f"[kv_chunk={self.kv_chunk}] {self.shape.describe()}")


@dataclasses.dataclass(frozen=True)
class Tiling:
    """3-D mapping of the GEMM onto the logical grid (paper §3.1).

    The logical grid (gm x gn x gk) has gm*gn*gk == n_tiles. gk == 1 is 2-D
    output-stationary tiling (one tile owns one output tile); gk > 1 is 3-D
    split-K (gk tiles collaborate on one output tile and NoC-reduce partials).
    iter_m/iter_n/iter_k sweep the grid over GEMMs bigger than one coverage.
    """
    gm: int
    gn: int
    gk: int = 1
    iter_m: int = 1
    iter_n: int = 1
    tk: int = 128               # K-chunk per superstep (L1-resident)

    def tile_dims(self, shape: GEMMShape) -> Tuple[int, int, int]:
        """(TM, TN, K_local): the per-tile workload."""
        tm = shape.m // (self.gm * self.iter_m)
        tn = shape.n // (self.gn * self.iter_n)
        k_local = shape.k // self.gk
        return tm, tn, k_local

    def validate(self, shape: GEMMShape, n_tiles: int) -> None:
        if self.gm * self.gn * self.gk != n_tiles:
            raise ValueError(f"{self.gm}x{self.gn}x{self.gk} != {n_tiles} tiles")
        if shape.m % (self.gm * self.iter_m):
            raise ValueError(f"M={shape.m} not divisible by gm*iter_m="
                             f"{self.gm * self.iter_m}")
        if shape.n % (self.gn * self.iter_n):
            raise ValueError(f"N={shape.n} not divisible by gn*iter_n="
                             f"{self.gn * self.iter_n}")
        if shape.k % self.gk:
            raise ValueError(f"K={shape.k} not divisible by gk={self.gk}")
        k_local = shape.k // self.gk
        if k_local % self.tk and k_local > self.tk:
            raise ValueError(f"K_local={k_local} not divisible by tk={self.tk}")


@dataclasses.dataclass(frozen=True)
class InnerKernel:
    """Second schedule level: the intra-device (per-tile) kernel geometry.

    The outer `Tiling` maps the GEMM onto the tile grid; an `InnerKernel`
    maps each tile's local (TM x TN x K) contraction onto its matrix engine
    — block shape, operand pipeline depth, and compute element dtype. On the
    TPU target it parameterizes the Pallas `kernels/mmad` kernel (BlockSpec
    geometry + double-buffered VMEM streaming); in the cost model it prices
    MXU occupancy, pipeline refills per `bk` chunk, and the feed bandwidth
    at the kernel's element width. Frozen + hashable so it can ride on a
    `Schedule`, an `ExecPlan`, and through `jax.custom_vjp` nondiff args.
    """
    bm: int
    bn: int
    bk: int
    # operand pipeline depth: 2 = double-buffered (the next block streams
    # while the current one computes), 1 = serialized fetch/compute.
    depth: int = 2
    # compute element dtype (accumulation is always fp32); "" inherits the
    # schedule's element dtype at dispatch/pricing time.
    dtype: str = ""

    def elem_bytes(self, default: int = 4) -> int:
        return ELEM_BYTES_OF_DTYPE.get(self.dtype, default)

    def geometry(self) -> Tuple[int, int, int]:
        return (self.bm, self.bn, self.bk)

    def working_set_bytes(self, default_elem_bytes: int = 4) -> int:
        """Pipelined A/B blocks + the fp32 accumulator block."""
        eb = self.elem_bytes(default_elem_bytes)
        depth = max(1, self.depth)
        return ((self.bm * self.bk + self.bk * self.bn) * eb * depth
                + self.bm * self.bn * 4)

    def validate(self, budget: int = INNER_VMEM_BUDGET) -> None:
        """Legality rules, mirroring `Tiling.validate`."""
        if min(self.bm, self.bn, self.bk) < 1:
            raise ValueError(f"inner kernel blocks must be positive, got "
                             f"{self.bm}x{self.bn}x{self.bk}")
        if self.depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {self.depth}")
        if self.dtype and self.dtype not in ELEM_BYTES_OF_DTYPE:
            raise ValueError(f"unknown inner-kernel dtype {self.dtype!r}; "
                             f"have {sorted(ELEM_BYTES_OF_DTYPE)}")
        ws = self.working_set_bytes()
        if ws > budget:
            raise ValueError(f"inner-kernel working set {ws} exceeds the "
                             f"{budget}-byte VMEM budget")

    def to_dict(self) -> Dict[str, object]:
        return {"bm": self.bm, "bn": self.bn, "bk": self.bk,
                "depth": self.depth, "dtype": self.dtype}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "InnerKernel":
        return cls(bm=int(d["bm"]), bn=int(d["bn"]), bk=int(d["bk"]),
                   depth=int(d.get("depth", 2)),
                   dtype=str(d.get("dtype", "")))

    def describe(self) -> str:
        dt = f":{self.dtype}" if self.dtype else ""
        return f"{self.bm}x{self.bn}x{self.bk}d{self.depth}{dt}"


# every name has both a BSP builder (build_program, simulator/cost model)
# and an explicit mesh lowering (repro.core.lower) — the two hierarchical
# compositions resolve to distinct ExecPlan modes (systolic_over_summa ->
# outer_systolic, summa_over_systolic -> hierarchical); docs/dataflows.md
# tabulates the full mapping and its fallback chains.
DATAFLOWS = ("baseline", "summa", "systolic", "systolic_over_summa",
             "summa_over_systolic", "splitk_summa")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One point in DiT's deployment space."""
    shape: GEMMShape
    tiling: Tiling
    dataflow: str = "summa"
    remap: Optional[ClusterRemap] = None      # None -> identity (logical == physical)
    # layouts keyed by matrix name; None -> optimal_layout for that matrix.
    layouts: Optional[Dict[str, layout_lib.DataLayout]] = None
    double_buffer: bool = True
    # store pipeline stages for store-intensive cases (paper Insight 2 / Fig 8b)
    store_stages: int = 1
    # hierarchical schedules: inner group shape on the logical grid
    inner: Tuple[int, int] = (2, 2)
    # reduction-owner policy for split-K: which K-slice owner commits C
    reduce_owner: str = "first"               # 'first' | 'round_robin'
    elem_bytes: int = 4
    # L1 accumulator precision (4 = fp32; 2 models fp16 accumulation, which
    # the fp8 deployment needs for very large C tiles to fit 384 KB L1).
    acc_bytes: int = 4
    # explicit element dtype name ("" = resolved from elem_bytes + the
    # hardware's native dtype; see `elem_dtype_name`) — fp8 deployments
    # price and lower as float8_e4m3, not as the byte-width's int8 default.
    elem_dtype: str = ""
    # second schedule level: the per-tile kernel geometry (None = the
    # target's default kernel, i.e. whatever XLA picks for the local dot).
    inner_kernel: Optional[InnerKernel] = None
    # overlap the ring dataflows' ppermute hops with inner-tile compute
    # (issue the collective for step s+1 before consuming step s's panels).
    # No-op for the broadcast dataflows, which consume a panel in the same
    # superstep it arrives.
    overlap: bool = False

    def describe(self) -> str:
        t = self.tiling
        r = f" remap={self.remap.logical}" if self.remap else ""
        ik = f" ik={self.inner_kernel.describe()}" if self.inner_kernel else ""
        ov = " overlap" if self.overlap else ""
        return (f"{self.dataflow}[{t.gm}x{t.gn}x{t.gk} iters=({t.iter_m},{t.iter_n}) "
                f"tk={t.tk}]{r} db={int(self.double_buffer)} "
                f"stages={self.store_stages}{ik}{ov}")


# byte-width -> default dtype name, the legacy direction (re-exported by
# core.dataflow.common for its existing importers). Lossy on purpose — 1
# byte could be int8 OR float8_e4m3 — which is why `elem_dtype_name` below
# consults the schedule's and the hardware's explicit dtype first.
DTYPE_OF_BYTES = {1: "int8", 2: "float16", 4: "float32"}


def elem_dtype_name(sched: Schedule,
                    hw: Optional[AcceleratorConfig] = None) -> str:
    """The element dtype a schedule deploys under.

    Resolution order: the schedule's explicit `elem_dtype`; the hardware's
    native engine dtype when its byte width matches the schedule's
    `elem_bytes` (the GH200 preset's fp8); the legacy byte-width default.
    """
    if sched.elem_dtype:
        return sched.elem_dtype
    hw_dt = getattr(getattr(hw, "tile", None), "elem_dtype", "")
    if hw_dt and ELEM_BYTES_OF_DTYPE.get(hw_dt) == sched.elem_bytes:
        return hw_dt
    return DTYPE_OF_BYTES[sched.elem_bytes]


def default_elem_dtype(elem_bytes: int,
                       hw: Optional[AcceleratorConfig] = None) -> str:
    """`elem_dtype_name` for candidate generators that only have the byte
    width: the hardware's native dtype when the widths agree, else the
    legacy byte-width default."""
    hw_dt = getattr(getattr(hw, "tile", None), "elem_dtype", "")
    if hw_dt and ELEM_BYTES_OF_DTYPE.get(hw_dt) == elem_bytes:
        return hw_dt
    return DTYPE_OF_BYTES[elem_bytes]


def _aligned_block(dim: int, unit: int) -> int:
    """Largest of {4, 2, 1} x `unit` that divides `dim` (falling back to the
    dim itself) — an engine-aligned block edge with no padding waste."""
    for mult in (4, 2, 1):
        b = unit * mult
        if b <= dim and dim % b == 0:
            return b
    return dim


def inner_kernel_candidates(sched: Schedule, hw: AcceleratorConfig,
                            max_candidates: int = 3) -> Tuple[InnerKernel, ...]:
    """Closed-form inner-kernel shortlist for one outer schedule.

    Mirrors the analytic shortlist's derivation style at the second tiling
    level: block edges are the largest engine-aligned divisors of the tile
    dims (MXU occupancy), `bk` sweeps down from the full K-chunk (larger bk
    amortizes pipeline refills and the accumulator flush), and the pipeline
    depth degrades from double-buffered to serialized only when the deeper
    working set cannot fit the VMEM budget. Deterministic and ordered
    best-prior-first, so the pricing sweep's tie-break (first strict
    minimum wins) prefers the planner-visible kernel over the opaque
    XLA-default path at equal predicted cost.
    """
    tm, tn, k_local = sched.tiling.tile_dims(sched.shape)
    tk = min(sched.tiling.tk, k_local)
    if min(tm, tn, tk) < 1:
        return ()
    dtype = elem_dtype_name(sched, hw)
    t = hw.tile
    bm = _aligned_block(tm, t.ce_rows)
    bn = _aligned_block(tn, t.ce_cols)
    budget = min(t.l1_bytes, INNER_VMEM_BUDGET)
    out = []
    for bk in (tk, tk // 2, tk // 4):
        if bk < 1 or tk % bk:
            continue
        for depth in (2, 1):
            ik = InnerKernel(bm, bn, bk, depth=depth, dtype=dtype)
            if ik.working_set_bytes() <= budget:
                out.append(ik)
                break           # deeper pipeline strictly dominates at a bk
        if len(out) >= max_candidates:
            break
    return tuple(out)


def resolve_layouts(sched: Schedule, hw: AcceleratorConfig) -> Dict[str, layout_lib.DataLayout]:
    """Fill in default (optimal) layouts for matrices the user didn't pin."""
    tm, tn, k_local = sched.tiling.tile_dims(sched.shape)
    tk = min(sched.tiling.tk, k_local)
    shapes = {"A": (sched.shape.m, sched.shape.k),
              "B": (sched.shape.k, sched.shape.n),
              "C": (sched.shape.m, sched.shape.n)}
    tiles = {"A": (tm, tk), "B": (tk, tn), "C": (tm, tn)}
    out = dict(sched.layouts or {})
    for mat, shp in shapes.items():
        if mat not in out:
            out[mat] = layout_lib.optimal_layout(shp, *tiles[mat], hw.hbm.n_channels)
    return out


def build_program(sched: Schedule, hw: AcceleratorConfig) -> Program:
    """Dispatch to the dataflow pattern builders (paper §3.3.2)."""
    from repro.core.dataflow import baseline, hierarchical, splitk, summa, systolic
    sched.tiling.validate(sched.shape, hw.n_tiles)
    builders = {
        "baseline": baseline.build,
        "summa": summa.build,
        "systolic": systolic.build,
        "systolic_over_summa": hierarchical.build_systolic_over_summa,
        "summa_over_systolic": hierarchical.build_summa_over_systolic,
        "splitk_summa": splitk.build,
    }
    if sched.dataflow not in builders:
        raise KeyError(f"unknown dataflow {sched.dataflow!r}; have {DATAFLOWS}")
    prog = builders[sched.dataflow](sched, hw)
    prog.validate(l1_capacity=hw.tile.l1_bytes)
    return prog
