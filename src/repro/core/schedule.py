"""Deployment-schedule abstraction (paper §3).

A `Schedule` is the complete, parameterizable description DiT generates code
from: (1) tiling & mapping — how the GEMM is decomposed over the logical tile
grid, including 3-D split-K and cluster index remap; (2) data layout — split +
placement schemes per matrix; (3) dataflow — which pattern primitive moves the
data (baseline / SUMMA / systolic / hierarchical / split-K) and its knobs
(double buffering, store pipeline stages).

`build_program(schedule, hw)` dispatches to the dataflow builders and returns
the BSP `Program` that the simulator executes and the cost model prices.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core import layout as layout_lib
from repro.core.ir import Program
from repro.core.remap import ClusterRemap
from repro.hw.config import AcceleratorConfig


@dataclasses.dataclass(frozen=True)
class GEMMShape:
    m: int
    n: int
    k: int

    def flops(self) -> int:
        return 2 * self.m * self.n * self.k

    def min_bytes(self, elem_bytes: int = 4) -> int:
        """Compulsory HBM traffic: read A and B once, write C once."""
        return elem_bytes * (self.m * self.k + self.k * self.n + self.m * self.n)

    def intensity(self, elem_bytes: int = 4) -> float:
        return self.flops() / self.min_bytes(elem_bytes)


@dataclasses.dataclass(frozen=True)
class Tiling:
    """3-D mapping of the GEMM onto the logical grid (paper §3.1).

    The logical grid (gm x gn x gk) has gm*gn*gk == n_tiles. gk == 1 is 2-D
    output-stationary tiling (one tile owns one output tile); gk > 1 is 3-D
    split-K (gk tiles collaborate on one output tile and NoC-reduce partials).
    iter_m/iter_n/iter_k sweep the grid over GEMMs bigger than one coverage.
    """
    gm: int
    gn: int
    gk: int = 1
    iter_m: int = 1
    iter_n: int = 1
    tk: int = 128               # K-chunk per superstep (L1-resident)

    def tile_dims(self, shape: GEMMShape) -> Tuple[int, int, int]:
        """(TM, TN, K_local): the per-tile workload."""
        tm = shape.m // (self.gm * self.iter_m)
        tn = shape.n // (self.gn * self.iter_n)
        k_local = shape.k // self.gk
        return tm, tn, k_local

    def validate(self, shape: GEMMShape, n_tiles: int) -> None:
        if self.gm * self.gn * self.gk != n_tiles:
            raise ValueError(f"{self.gm}x{self.gn}x{self.gk} != {n_tiles} tiles")
        if shape.m % (self.gm * self.iter_m):
            raise ValueError(f"M={shape.m} not divisible by gm*iter_m="
                             f"{self.gm * self.iter_m}")
        if shape.n % (self.gn * self.iter_n):
            raise ValueError(f"N={shape.n} not divisible by gn*iter_n="
                             f"{self.gn * self.iter_n}")
        if shape.k % self.gk:
            raise ValueError(f"K={shape.k} not divisible by gk={self.gk}")
        k_local = shape.k // self.gk
        if k_local % self.tk and k_local > self.tk:
            raise ValueError(f"K_local={k_local} not divisible by tk={self.tk}")


# every name has both a BSP builder (build_program, simulator/cost model)
# and an explicit mesh lowering (repro.core.lower) — the two hierarchical
# compositions resolve to distinct ExecPlan modes (systolic_over_summa ->
# outer_systolic, summa_over_systolic -> hierarchical); docs/dataflows.md
# tabulates the full mapping and its fallback chains.
DATAFLOWS = ("baseline", "summa", "systolic", "systolic_over_summa",
             "summa_over_systolic", "splitk_summa")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One point in DiT's deployment space."""
    shape: GEMMShape
    tiling: Tiling
    dataflow: str = "summa"
    remap: Optional[ClusterRemap] = None      # None -> identity (logical == physical)
    # layouts keyed by matrix name; None -> optimal_layout for that matrix.
    layouts: Optional[Dict[str, layout_lib.DataLayout]] = None
    double_buffer: bool = True
    # store pipeline stages for store-intensive cases (paper Insight 2 / Fig 8b)
    store_stages: int = 1
    # hierarchical schedules: inner group shape on the logical grid
    inner: Tuple[int, int] = (2, 2)
    # reduction-owner policy for split-K: which K-slice owner commits C
    reduce_owner: str = "first"               # 'first' | 'round_robin'
    elem_bytes: int = 4
    # L1 accumulator precision (4 = fp32; 2 models fp16 accumulation, which
    # the fp8 deployment needs for very large C tiles to fit 384 KB L1).
    acc_bytes: int = 4

    def describe(self) -> str:
        t = self.tiling
        r = f" remap={self.remap.logical}" if self.remap else ""
        return (f"{self.dataflow}[{t.gm}x{t.gn}x{t.gk} iters=({t.iter_m},{t.iter_n}) "
                f"tk={t.tk}]{r} db={int(self.double_buffer)} stages={self.store_stages}")


def resolve_layouts(sched: Schedule, hw: AcceleratorConfig) -> Dict[str, layout_lib.DataLayout]:
    """Fill in default (optimal) layouts for matrices the user didn't pin."""
    tm, tn, k_local = sched.tiling.tile_dims(sched.shape)
    tk = min(sched.tiling.tk, k_local)
    shapes = {"A": (sched.shape.m, sched.shape.k),
              "B": (sched.shape.k, sched.shape.n),
              "C": (sched.shape.m, sched.shape.n)}
    tiles = {"A": (tm, tk), "B": (tk, tn), "C": (tm, tn)}
    out = dict(sched.layouts or {})
    for mat, shp in shapes.items():
        if mat not in out:
            out[mat] = layout_lib.optimal_layout(shp, *tiles[mat], hw.hbm.n_channels)
    return out


def build_program(sched: Schedule, hw: AcceleratorConfig) -> Program:
    """Dispatch to the dataflow pattern builders (paper §3.3.2)."""
    from repro.core.dataflow import baseline, hierarchical, splitk, summa, systolic
    sched.tiling.validate(sched.shape, hw.n_tiles)
    builders = {
        "baseline": baseline.build,
        "summa": summa.build,
        "systolic": systolic.build,
        "systolic_over_summa": hierarchical.build_systolic_over_summa,
        "summa_over_systolic": hierarchical.build_summa_over_systolic,
        "splitk_summa": splitk.build,
    }
    if sched.dataflow not in builders:
        raise KeyError(f"unknown dataflow {sched.dataflow!r}; have {DATAFLOWS}")
    prog = builders[sched.dataflow](sched, hw)
    prog.validate(l1_capacity=hw.tile.l1_bytes)
    return prog
