"""Systolic dataflow (paper §3.3.2, Fig. 6b).

Output-stationary systolic GEMM: A tiles propagate rightward, B tiles
propagate downward, computation proceeds as a spatial wavefront driven
entirely by nearest-neighbour communication. Tile (i, j) consumes k-chunk t
at superstep t + i + j; west-edge tiles inject A from HBM, north-edge tiles
inject B. Loads are naturally staggered across supersteps (no HBM burst),
but the wavefront costs gm + gn - 2 fill supersteps — the pipelining
trade-off of Fig. 8.

Mesh-execution analogue: `dit_gemm` mode `cannon` (docs/dataflows.md).
"""
from __future__ import annotations

from repro.core.dataflow.common import GridView
from repro.core.ir import DMAOp, MMADOp, P2POp, Program, Superstep
from repro.core.schedule import Schedule
from repro.hw.config import AcceleratorConfig


def build(sched: Schedule, hw: AcceleratorConfig) -> Program:
    if sched.tiling.gk != 1:
        raise ValueError("systolic dataflow is 2-D (gk must be 1)")
    g = GridView(sched, hw)
    # systolic needs 2 slots even without the double_buffer flag: a tile
    # forwards chunk t while computing on it; flag only controls overlap of
    # injection DMA (modelled identically here).
    prog = g.make_program(g.std_buffers(), name="systolic")
    for b in prog.buffers.values():
        if b.name in ("A", "B"):
            b.slots = 2

    for om in range(g.iter_m):
        for on in range(g.iter_n):
            total = g.n_ksteps + g.gm + g.gn - 2
            # superstep s = -1 .. total-1; s covers injections for arrival at s+1
            for s in range(-1, total):
                step = Superstep(label=f"i{om},{on} s{s}")
                # compute: tile (lm, ln) works on chunk t = s - lm - ln
                for lm in range(g.gm):
                    for ln in range(g.gn):
                        t = s - lm - ln
                        if 0 <= t < g.n_ksteps:
                            step.compute.append(MMADOp(
                                g.coord(lm, ln), "A", t % 2, "B", t % 2, "C", 0,
                                init=(t == 0), tm=g.tm, tn=g.tn, tk=g.tk))
                # propagation: tile holding chunk t at step s forwards it for
                # arrival at s+1 (east for A, south for B).
                for lm in range(g.gm):
                    for ln in range(g.gn):
                        t = s - lm - ln
                        if 0 <= t < g.n_ksteps:
                            if ln + 1 < g.gn:
                                step.comm.append(P2POp(g.coord(lm, ln),
                                                       g.coord(lm, ln + 1), "A", t % 2))
                            if lm + 1 < g.gm:
                                step.comm.append(P2POp(g.coord(lm, ln),
                                                       g.coord(lm + 1, ln), "B", t % 2))
                # injection: west edge loads A(lm, t') arriving at s+1 = t' + lm
                for lm in range(g.gm):
                    t_in = s + 1 - lm
                    if 0 <= t_in < g.n_ksteps:
                        step.comm.append(DMAOp(g.coord(lm, 0), "load", "A",
                                               g.a_tile(om, lm, t_in), "A", t_in % 2))
                for ln in range(g.gn):
                    t_in = s + 1 - ln
                    if 0 <= t_in < g.n_ksteps:
                        step.comm.append(DMAOp(g.coord(0, ln), "load", "B",
                                               g.b_tile(on, ln, t_in), "B", t_in % 2))
                if step.compute or step.comm:
                    prog.add(step)
            # drain: store C
            stages = max(1, sched.store_stages)
            n_tiles = g.gm * g.gn
            stores = [DMAOp(g.coord(lm, ln), "store", "C",
                            g.c_tile(om, on, lm, ln), "C", 0)
                      for lm in range(g.gm) for ln in range(g.gn)]
            per = (n_tiles + stages - 1) // stages
            for s0 in range(0, n_tiles, per):
                prog.add(Superstep(comm=stores[s0:s0 + per], label=f"i{om},{on} store"))
    return prog
