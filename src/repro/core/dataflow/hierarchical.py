"""Hierarchical dataflow schedules (paper §3.3.2, Fig. 6c/6d).

The logical grid is partitioned into an outer (Om x On) grid of inner
(ih x iw) tile groups (sched.inner; square groups required, as in the paper's
2x2-over-2x2 example). Two compositions:

- **systolic over SUMMA** (Fig. 6c): each inner group runs SUMMA on its
  reduced (M/Om x N/On x K) subproblem while A/B chunks propagate between
  groups as a global systolic wavefront (group (oi,oj) consumes outer k-chunk
  t at outer step t + oi + oj).
- **SUMMA over systolic** (Fig. 6d): each inner group runs a local systolic
  GEMM while the outer grid executes SUMMA propagation — owner groups
  multicast A strips along outer rows / B strips down outer columns (strided
  mask groups), and all groups start every chunk simultaneously.

All collectives here are single hardware mask collectives: inner rows/cols and
outer-strided rows/cols fix aligned power-of-2 bit-ranges of the flat index.

Mesh-execution analogue: each composition lowers (via
`repro.core.lower.lower_schedule`) to its OWN `dit_gemm` mode on a 4-axis
mesh view — `summa_over_systolic` (Fig. 6d) to `hierarchical` (outer SUMMA
over inner Cannon groups) and `systolic_over_summa` (Fig. 6c) to
`outer_systolic` (an outer Cannon ring of inner SUMMA groups; the
group-to-group hold propagation below becomes `ppermute` ring steps over
the outer mesh axes). Fig. 6c needs a square outer grid of at least 2×2
for its ring and falls back to `hierarchical` otherwise, with the reason
recorded — see docs/dataflows.md ("Fig. 6c vs 6d") for the side-by-side
collective patterns and fallback chains.
"""
from __future__ import annotations

from repro.core.dataflow.common import GridView
from repro.core.ir import BufferDecl, DMAOp, MMADOp, MulticastOp, P2POp, Program, Superstep
from repro.core.masks import TileGroup
from repro.core.remap import flat_mask_group
from repro.core.schedule import Schedule
from repro.hw.config import AcceleratorConfig


class _HierView(GridView):
    """GridView + inner/outer group index algebra (gk must be 1)."""

    def setup(self, inner):
        self.ih, self.iw = inner
        if self.ih != self.iw:
            raise ValueError(f"hierarchical schedules need square inner groups, got {inner}")
        if self.gm % self.ih or self.gn % self.iw:
            raise ValueError(f"inner {inner} must divide logical grid ({self.gm}x{self.gn})")
        self.Om, self.On = self.gm // self.ih, self.gn // self.iw
        self._full2 = self.gm * self.gn - 1
        self._gnb = (self.gn - 1).bit_length()

    def lcoord(self, oi, oj, li, lj):
        return self.coord(oi * self.ih + li, oj * self.iw + lj)

    def inner_row_group(self, oi, oj, li) -> TileGroup:
        """{(oi*ih+li, oj*iw + *)} — lj free."""
        sel = (oi * self.ih + li) * self.gn + oj * self.iw
        return flat_mask_group(sel, self._full2 & ~(self.iw - 1), self.phys)

    def inner_col_group(self, oi, oj, lj) -> TileGroup:
        """{(oi*ih + *, oj*iw+lj)} — li free."""
        sel = (oi * self.ih) * self.gn + oj * self.iw + lj
        free = (self.ih - 1) << self._gnb
        return flat_mask_group(sel, self._full2 & ~free, self.phys)

    def outer_row_group(self, oi, li, lj) -> TileGroup:
        """Counterpart tiles (li, lj) of every group in outer row oi — oj free."""
        sel = (oi * self.ih + li) * self.gn + lj
        free = (self.On - 1) * self.iw
        return flat_mask_group(sel, self._full2 & ~free, self.phys)

    def outer_col_group(self, oj, li, lj) -> TileGroup:
        """Counterpart tiles (li, lj) of every group in outer col oj — oi free."""
        sel = li * self.gn + oj * self.iw + lj
        free = ((self.Om - 1) * self.ih) << self._gnb
        return flat_mask_group(sel, self._full2 & ~free, self.phys)

    def final_stores(self, prog, sched, om, on):
        stores = [DMAOp(self.coord(lm, ln), "store", "C",
                        self.c_tile(om, on, lm, ln), "C", 0)
                  for lm in range(self.gm) for ln in range(self.gn)]
        stages = max(1, sched.store_stages)
        per = (len(stores) + stages - 1) // stages
        for s0 in range(0, len(stores), per):
            prog.add(Superstep(comm=stores[s0:s0 + per], label="store"))


# ---------------------------------------------------------------------------
# Fig. 6c — systolic over SUMMA
# ---------------------------------------------------------------------------
#
# Hold distribution: tile (li, lj) of a group holds A(row li, inner chunk lj)
# in Ahold and B(inner chunk li, col lj) in Bhold (square groups: both chunk
# indices range over ih == iw). Outer chunk t moves group-to-group by P2P of
# the holds; the inner SUMMA multicasts hold slices with tau-parity working
# slots.

def build_systolic_over_summa(sched: Schedule, hw: AcceleratorConfig) -> Program:
    if sched.tiling.gk != 1:
        raise ValueError("hierarchical dataflows are 2-D (gk must be 1)")
    g = _HierView(sched, hw)
    g.setup(sched.inner)
    if g.n_ksteps % g.iw:
        raise ValueError(f"n_ksteps={g.n_ksteps} must divide by inner width {g.iw}")
    n_outer = g.n_ksteps // g.iw   # outer chunks; each holds iw inner tk-chunks
    n_inner = g.iw
    dt = g.dtype()
    bufs = g.std_buffers()
    # the wavefront always needs 2 working slots (compute t, receive t+1)
    bufs["A"].slots = bufs["B"].slots = 2
    bufs["Ahold"] = BufferDecl("Ahold", (g.tm, g.tk), slots=2, dtype=dt)
    bufs["Bhold"] = BufferDecl("Bhold", (g.tk, g.tn), slots=2, dtype=dt)
    prog = g.make_program(bufs, name="systolic_over_summa")

    def active(s):
        for oi in range(g.Om):
            for oj in range(g.On):
                t = s - oi - oj
                if 0 <= t < n_outer:
                    yield oi, oj, t

    for om in range(g.iter_m):
        for on in range(g.iter_n):
            total = n_outer + g.Om + g.On - 2
            for s in range(-1, total):
                # pre-superstep: systolic hop of holds, HBM injection for s+1,
                # and the inner multicast of chunk tau=0 for this outer step.
                pre = Superstep(label=f"s{s} pre")
                for oi, oj, t in active(s):
                    for li in range(g.ih):
                        for lj in range(g.iw):
                            src = g.lcoord(oi, oj, li, lj)
                            if oj + 1 < g.On:
                                pre.comm.append(P2POp(src, g.lcoord(oi, oj + 1, li, lj),
                                                      "Ahold", t % 2))
                            if oi + 1 < g.Om:
                                pre.comm.append(P2POp(src, g.lcoord(oi + 1, oj, li, lj),
                                                      "Bhold", t % 2))
                for oi in range(g.Om):           # west-edge A injection
                    t_in = s + 1 - oi
                    if 0 <= t_in < n_outer:
                        for li in range(g.ih):
                            for lj in range(g.iw):
                                pre.comm.append(DMAOp(
                                    g.lcoord(oi, 0, li, lj), "load", "A",
                                    g.a_tile(om, oi * g.ih + li, t_in * n_inner + lj),
                                    "Ahold", t_in % 2))
                for oj in range(g.On):           # north-edge B injection
                    t_in = s + 1 - oj
                    if 0 <= t_in < n_outer:
                        for li in range(g.ih):
                            for lj in range(g.iw):
                                pre.comm.append(DMAOp(
                                    g.lcoord(0, oj, li, lj), "load", "B",
                                    g.b_tile(on, oj * g.iw + lj, t_in * n_inner + li),
                                    "Bhold", t_in % 2))
                for oi, oj, t in active(s):      # inner SUMMA multicast tau=0
                    for li in range(g.ih):
                        pre.comm.append(MulticastOp(
                            g.lcoord(oi, oj, li, 0), g.inner_row_group(oi, oj, li),
                            "Ahold", t % 2, dst_buf="A", dst_slot=0))
                    for lj in range(g.iw):
                        pre.comm.append(MulticastOp(
                            g.lcoord(oi, oj, 0, lj), g.inner_col_group(oi, oj, lj),
                            "Bhold", t % 2, dst_buf="B", dst_slot=0))
                if pre.comm:
                    prog.add(pre)
                # inner SUMMA steps tau = 0..n_inner-1 with tau-parity slots.
                for tau in range(n_inner):
                    step = Superstep(label=f"s{s} tau{tau}")
                    for oi, oj, t in active(s):
                        for li in range(g.ih):
                            for lj in range(g.iw):
                                step.compute.append(MMADOp(
                                    g.lcoord(oi, oj, li, lj), "A", tau % 2,
                                    "B", tau % 2, "C", 0,
                                    init=(t == 0 and tau == 0),
                                    tm=g.tm, tn=g.tn, tk=g.tk))
                        if tau + 1 < n_inner:
                            for li in range(g.ih):
                                step.comm.append(MulticastOp(
                                    g.lcoord(oi, oj, li, tau + 1),
                                    g.inner_row_group(oi, oj, li),
                                    "Ahold", t % 2, dst_buf="A", dst_slot=(tau + 1) % 2))
                            for lj in range(g.iw):
                                step.comm.append(MulticastOp(
                                    g.lcoord(oi, oj, tau + 1, lj),
                                    g.inner_col_group(oi, oj, lj),
                                    "Bhold", t % 2, dst_buf="B", dst_slot=(tau + 1) % 2))
                    if step.compute or step.comm:
                        prog.add(step)
            g.final_stores(prog, sched, om, on)
    return prog


# ---------------------------------------------------------------------------
# Fig. 6d — SUMMA over systolic
# ---------------------------------------------------------------------------

def build_summa_over_systolic(sched: Schedule, hw: AcceleratorConfig) -> Program:
    if sched.tiling.gk != 1:
        raise ValueError("hierarchical dataflows are 2-D (gk must be 1)")
    g = _HierView(sched, hw)
    g.setup(sched.inner)
    n_inner = g.iw                     # inner tk-chunks per outer SUMMA step
    if g.n_ksteps % n_inner:
        raise ValueError(f"n_ksteps={g.n_ksteps} must divide by inner width {g.iw}")
    n_outer = g.n_ksteps // n_inner
    dt = g.dtype()
    bufs = g.std_buffers()
    # the wavefront always needs 2 working slots (compute t, receive t+1)
    bufs["A"].slots = bufs["B"].slots = 2
    # full strip of the outer chunk on west/north counterpart tiles; one slot
    # per inner chunk so the systolic feed can index chunk tau directly.
    bufs["Afeed"] = BufferDecl("Afeed", (g.tm, g.tk), slots=n_inner, dtype=dt)
    bufs["Bfeed"] = BufferDecl("Bfeed", (g.tk, g.tn), slots=n_inner, dtype=dt)
    prog = g.make_program(bufs, name="summa_over_systolic")

    for om in range(g.iter_m):
        for on in range(g.iter_n):
            for T in range(n_outer):
                # owner groups DMA strips (one DMA per inner chunk slot).
                load = Superstep(label=f"T{T} load")
                for oi in range(g.Om):
                    for li in range(g.ih):
                        for tau in range(n_inner):
                            load.comm.append(DMAOp(
                                g.lcoord(oi, T % g.On, li, 0), "load", "A",
                                g.a_tile(om, oi * g.ih + li, T * n_inner + tau),
                                "Afeed", tau))
                for oj in range(g.On):
                    for lj in range(g.iw):
                        for tau in range(n_inner):
                            load.comm.append(DMAOp(
                                g.lcoord(T % g.Om, oj, 0, lj), "load", "B",
                                g.b_tile(on, oj * g.iw + lj, T * n_inner + tau),
                                "Bfeed", tau))
                prog.add(load)
                # outer SUMMA multicast to west/north counterparts of every group.
                mc = Superstep(label=f"T{T} outer-mcast")
                for oi in range(g.Om):
                    for li in range(g.ih):
                        for tau in range(n_inner):
                            mc.comm.append(MulticastOp(
                                g.lcoord(oi, T % g.On, li, 0),
                                g.outer_row_group(oi, li, 0), "Afeed", tau))
                for oj in range(g.On):
                    for lj in range(g.iw):
                        for tau in range(n_inner):
                            mc.comm.append(MulticastOp(
                                g.lcoord(T % g.Om, oj, 0, lj),
                                g.outer_col_group(oj, 0, lj), "Bfeed", tau))
                prog.add(mc)
                # inner systolic wavefront over the strip (all groups at once).
                total = n_inner + g.ih + g.iw - 2
                for sg in range(-1, total):
                    step = Superstep(label=f"T{T} sg{sg}")
                    for oi in range(g.Om):
                        for oj in range(g.On):
                            for li in range(g.ih):
                                for lj in range(g.iw):
                                    tile = g.lcoord(oi, oj, li, lj)
                                    tau = sg - li - lj
                                    if 0 <= tau < n_inner:
                                        step.compute.append(MMADOp(
                                            tile, "A", tau % 2, "B", tau % 2, "C", 0,
                                            init=(T == 0 and tau == 0),
                                            tm=g.tm, tn=g.tn, tk=g.tk))
                                        if lj + 1 < g.iw:
                                            step.comm.append(P2POp(
                                                tile, g.lcoord(oi, oj, li, lj + 1),
                                                "A", tau % 2))
                                        if li + 1 < g.ih:
                                            step.comm.append(P2POp(
                                                tile, g.lcoord(oi, oj, li + 1, lj),
                                                "B", tau % 2))
                                    # west/north edge feeds for arrival at sg+1
                                    if lj == 0:
                                        ti = sg + 1 - li
                                        if 0 <= ti < n_inner:
                                            step.comm.append(P2POp(
                                                tile, tile, "Afeed", ti,
                                                dst_buf="A", dst_slot=ti % 2))
                                    if li == 0:
                                        tj = sg + 1 - lj
                                        if 0 <= tj < n_inner:
                                            step.comm.append(P2POp(
                                                tile, tile, "Bfeed", tj,
                                                dst_buf="B", dst_slot=tj % 2))
                    if step.compute or step.comm:
                        prog.add(step)
            g.final_stores(prog, sched, om, on)
    return prog
