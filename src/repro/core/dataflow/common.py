"""Shared machinery for the dataflow pattern builders (paper §3.3.2).

`GridView` resolves a Schedule's logical (gm x gn x gk) grid onto the physical
tile grid through the flat row-major index (the cluster-index-remap mechanism,
§3.1.2): flat = ((lm * gn) + ln) * gk + lk. Because every extent is a power of
two, each logical row / column / k-group fixes a bit-range of the flat index
and therefore lowers to ONE hardware mask collective (`flat_mask_group`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core.ir import BufferDecl, Program
from repro.core.masks import TileGroup, axis_bits
from repro.core.remap import flat_mask_group
from repro.core.schedule import (DTYPE_OF_BYTES, GEMMShape, Schedule,
                                 elem_dtype_name, resolve_layouts)
from repro.hw.config import AcceleratorConfig

__all__ = ["DTYPE_OF_BYTES", "GridView"]  # DTYPE_OF_BYTES re-exported for importers


@dataclasses.dataclass
class GridView:
    sched: Schedule
    hw: AcceleratorConfig

    def __post_init__(self):
        t = self.sched.tiling
        self.phys: Tuple[int, int] = self.hw.grid
        self.gm, self.gn, self.gk = t.gm, t.gn, t.gk
        self.tm, self.tn, self.k_local = t.tile_dims(self.sched.shape)
        self.tk = min(t.tk, self.k_local)
        self.n_ksteps = self.k_local // self.tk
        self.iter_m, self.iter_n = t.iter_m, t.iter_n
        self._gk_bits = axis_bits(self.gk) if self.gk > 1 else 0
        self._gn_bits = axis_bits(self.gn) if self.gn > 1 else 0
        self._full = self.gm * self.gn * self.gk - 1

    # -- logical <-> physical ------------------------------------------------

    def flat(self, lm: int, ln: int, lk: int = 0) -> int:
        return ((lm * self.gn) + ln) * self.gk + lk

    def coord(self, lm: int, ln: int, lk: int = 0) -> Tuple[int, int]:
        return divmod(self.flat(lm, ln, lk), self.phys[1])

    # -- collective groups (each is ONE mask collective) ----------------------

    def row_group(self, lm: int, lk: int = 0) -> TileGroup:
        """All tiles in logical row lm of k-slice lk ({ln} free)."""
        sel = self.flat(lm, 0, lk)
        free = ((self.gn - 1) << self._gk_bits)
        return flat_mask_group(sel, self._full & ~free, self.phys)

    def col_group(self, ln: int, lk: int = 0) -> TileGroup:
        """All tiles in logical column ln of k-slice lk ({lm} free)."""
        sel = self.flat(0, ln, lk)
        free = ((self.gm - 1) << (self._gk_bits + self._gn_bits))
        return flat_mask_group(sel, self._full & ~free, self.phys)

    def k_group(self, lm: int, ln: int) -> TileGroup:
        """All k-slice peers of output tile (lm, ln) ({lk} free) — the
        split-K reduction group."""
        sel = self.flat(lm, ln, 0)
        free = self.gk - 1
        return flat_mask_group(sel, self._full & ~free, self.phys)

    # -- buffer plan -----------------------------------------------------------

    def dtype(self) -> str:
        # schedule's explicit dtype > hardware's native engine dtype (when the
        # byte widths agree — the gh200 preset's fp8) > legacy byte default.
        return elem_dtype_name(self.sched, self.hw)

    def make_program(self, buffers: Dict[str, BufferDecl], name: str) -> Program:
        return Program(
            grid=self.phys,
            shape=(self.sched.shape.m, self.sched.shape.n, self.sched.shape.k),
            tile_shape=(self.tm, self.tn, self.tk),
            buffers=buffers,
            layouts=resolve_layouts(self.sched, self.hw),
            double_buffer=self.sched.double_buffer,
            name=name,
            elem_bytes=self.sched.elem_bytes,
        )

    def std_buffers(self, *, c_slots: int = 1) -> Dict[str, BufferDecl]:
        """A/B working buffers + C accumulator. Owners DMA straight into the
        working buffer and the fabric multicast chains off the DMA in the same
        superstep (after_dma), so no separate staging buffers are needed."""
        db = 2 if self.sched.double_buffer else 1
        dt = self.dtype()
        acc_dt = "float16" if self.sched.acc_bytes == 2 else "float32"
        return {
            "A": BufferDecl("A", (self.tm, self.tk), slots=db, dtype=dt),
            "B": BufferDecl("B", (self.tk, self.tn), slots=db, dtype=dt),
            "C": BufferDecl("C", (self.tm, self.tn), slots=c_slots, dtype=acc_dt),
        }

    # -- global tile coordinates ----------------------------------------------

    def a_tile(self, om: int, lm: int, kchunk: int, lk: int = 0) -> Tuple[int, int]:
        """(ti, tj) index of the A tile (TM x TK) for iteration om, logical row
        lm, k-chunk index kchunk within k-slice lk."""
        ti = om * self.gm + lm
        tj = lk * self.n_ksteps + kchunk
        return ti, tj

    def b_tile(self, on: int, ln: int, kchunk: int, lk: int = 0) -> Tuple[int, int]:
        ti = lk * self.n_ksteps + kchunk
        tj = on * self.gn + ln
        return ti, tj

    def c_tile(self, om: int, on: int, lm: int, ln: int) -> Tuple[int, int]:
        return om * self.gm + lm, on * self.gn + ln
