from repro.core.dataflow import baseline, hierarchical, splitk, summa, systolic

__all__ = ["baseline", "hierarchical", "splitk", "summa", "systolic"]
