"""Dataflow pattern builders (paper §3.3.2): lower a `Schedule` to the BSP
`Program` the SoftHier simulator executes and the cost model prices.

The same patterns run on real JAX device meshes via `repro.core.gemm`;
docs/dataflows.md tabulates the mode-by-mode collective patterns,
divisibility preconditions, and fallback behavior.
"""
from repro.core.dataflow import baseline, hierarchical, splitk, summa, systolic

__all__ = ["baseline", "hierarchical", "splitk", "summa", "systolic"]
