"""Baseline dataflow (paper §4.1.1): no NoC collectives, no data sharing.

Every tile independently DMAs its own A and B tiles from HBM each k-step —
the reference point without specialized placement or on-chip communication.
A's k-column is fetched by all gn tiles of a logical row (gn-fold HBM read
amplification; gm-fold for B), which is exactly why its operational intensity
is low in Fig. 7a.

Mesh-execution analogue: `dit_gemm` mode `allgather` (docs/dataflows.md).
"""
from __future__ import annotations

from repro.core.dataflow.common import GridView
from repro.core.ir import DMAOp, MMADOp, Program, Superstep
from repro.core.schedule import Schedule
from repro.hw.config import AcceleratorConfig


def build(sched: Schedule, hw: AcceleratorConfig) -> Program:
    if sched.tiling.gk != 1:
        raise ValueError("baseline dataflow is 2-D (gk must be 1)")
    g = GridView(sched, hw)
    prog = g.make_program(g.std_buffers(), name="baseline")
    db = sched.double_buffer

    def loads(om: int, on: int, t: int) -> list:
        slot = t % 2 if db else 0
        ops = []
        for lm in range(g.gm):
            for ln in range(g.gn):
                tile = g.coord(lm, ln)
                ops.append(DMAOp(tile, "load", "A", g.a_tile(om, lm, t), "A", slot))
                ops.append(DMAOp(tile, "load", "B", g.b_tile(on, ln, t), "B", slot))
        return ops

    for om in range(g.iter_m):
        for on in range(g.iter_n):
            # prologue: fetch chunk 0
            prog.add(Superstep(comm=loads(om, on, 0), label=f"i{om},{on} prologue"))
            for t in range(g.n_ksteps):
                step = Superstep(label=f"i{om},{on} k{t}")
                slot = t % 2 if db else 0
                for lm in range(g.gm):
                    for ln in range(g.gn):
                        step.compute.append(MMADOp(
                            g.coord(lm, ln), "A", slot, "B", slot, "C", 0,
                            init=(t == 0), tm=g.tm, tn=g.tn, tk=g.tk))
                if db and t + 1 < g.n_ksteps:
                    step.comm.extend(loads(om, on, t + 1))
                prog.add(step)
                if not db and t + 1 < g.n_ksteps:
                    prog.add(Superstep(comm=loads(om, on, t + 1),
                                       label=f"i{om},{on} load k{t+1}"))
            # store C (optionally split over stages)
            stages = max(1, sched.store_stages)
            rows_per_stage = max(1, g.gm // stages)
            for s0 in range(0, g.gm, rows_per_stage):
                step = Superstep(label=f"i{om},{on} store")
                for lm in range(s0, min(s0 + rows_per_stage, g.gm)):
                    for ln in range(g.gn):
                        step.comm.append(DMAOp(g.coord(lm, ln), "store", "C",
                                               g.c_tile(om, on, lm, ln), "C", 0))
                prog.add(step)
    return prog
