"""SUMMA dataflow (paper §3.3.2, Fig. 6a).

Classical SUMMA adapted to a machine whose data starts in distributed HBM:
each k-step, one owner tile per logical row DMA-loads the A tile and the
fabric multicast chains straight off the DMA (same superstep, `after_dma`) to
the whole row; one owner per logical column does the same for B. All tiles
then MMAD simultaneously — no wavefront, which is why SUMMA wins compute-bound
shapes (Fig. 8a) but suffers store bursts in store-bound shapes (Fig. 8b),
where `store_stages > 1` pipelines the C write-back into the next iteration's
compute supersteps.

Double-buffered pipeline (§3.3.1): superstep s computes chunk t from working
slot (t%2) while owners DMA-load + multicast chunk t+1 into slot ((t+1)%2) —
two slots per operand buffer, no separate staging.

Mesh-execution analogue: `dit_gemm` mode `summa` (docs/dataflows.md).
"""
from __future__ import annotations

from typing import List

from repro.core.dataflow.common import GridView
from repro.core.ir import DMAOp, MMADOp, MulticastOp, Program, Superstep
from repro.core.schedule import Schedule
from repro.hw.config import AcceleratorConfig


def _fetch_and_multicast(g: GridView, om: int, on: int, t: int, slot: int) -> List[object]:
    """Owner DMA load of k-chunk t + chained row/col multicast (one superstep)."""
    ops: List[object] = []
    for lm in range(g.gm):
        owner = g.coord(lm, t % g.gn)
        ops.append(DMAOp(owner, "load", "A", g.a_tile(om, lm, t), "A", slot))
        ops.append(MulticastOp(owner, g.row_group(lm), "A", slot, after_dma=True))
    for ln in range(g.gn):
        owner = g.coord(t % g.gm, ln)
        ops.append(DMAOp(owner, "load", "B", g.b_tile(on, ln, t), "B", slot))
        ops.append(MulticastOp(owner, g.col_group(ln), "B", slot, after_dma=True))
    return ops


def _stores(g: GridView, om: int, on: int, acc_slot: int) -> List[DMAOp]:
    return [DMAOp(g.coord(lm, ln), "store", "C", g.c_tile(om, on, lm, ln), "C", acc_slot)
            for lm in range(g.gm) for ln in range(g.gn)]


def build(sched: Schedule, hw: AcceleratorConfig) -> Program:
    if sched.tiling.gk != 1:
        raise ValueError("summa dataflow is 2-D; use splitk_summa for gk > 1")
    g = GridView(sched, hw)
    db = sched.double_buffer
    pipelined_store = sched.store_stages > 1
    c_slots = 2 if pipelined_store else 1
    prog = g.make_program(g.std_buffers(c_slots=c_slots), name="summa")

    pending_stores: List[DMAOp] = []
    store_quota = max(1, (g.gm * g.gn + sched.store_stages - 1) // sched.store_stages)
    it = 0
    for om in range(g.iter_m):
        for on in range(g.iter_n):
            acc_slot = it % c_slots
            if db:
                prog.add(Superstep(comm=_fetch_and_multicast(g, om, on, 0, 0),
                                   label=f"i{om},{on} pro"))
                for t in range(g.n_ksteps):
                    step = Superstep(label=f"i{om},{on} k{t}")
                    for lm in range(g.gm):
                        for ln in range(g.gn):
                            step.compute.append(MMADOp(
                                g.coord(lm, ln), "A", t % 2, "B", t % 2, "C",
                                acc_slot, init=(t == 0), tm=g.tm, tn=g.tn, tk=g.tk))
                    if t + 1 < g.n_ksteps:
                        step.comm.extend(_fetch_and_multicast(g, om, on, t + 1, (t + 1) % 2))
                    # pipelined store of the previous iteration's C (fixed
                    # per-stage quota so the drain always completes)
                    if pending_stores:
                        step.comm.extend(pending_stores[:store_quota])
                        del pending_stores[:store_quota]
                    prog.add(step)
            else:
                for t in range(g.n_ksteps):
                    prog.add(Superstep(comm=_fetch_and_multicast(g, om, on, t, 0),
                                       label=f"i{om},{on} fetch k{t}"))
                    step = Superstep(label=f"i{om},{on} k{t}")
                    for lm in range(g.gm):
                        for ln in range(g.gn):
                            step.compute.append(MMADOp(
                                g.coord(lm, ln), "A", 0, "B", 0, "C", acc_slot,
                                init=(t == 0), tm=g.tm, tn=g.tn, tk=g.tk))
                    prog.add(step)

            if pending_stores:
                # iteration had fewer k-steps than store stages: flush the rest
                prog.add(Superstep(comm=list(pending_stores), label="store flush"))
                pending_stores.clear()
            stores = _stores(g, om, on, acc_slot)
            if pipelined_store and not (om == g.iter_m - 1 and on == g.iter_n - 1):
                pending_stores = stores      # drain into the next iteration
            else:
                stages = max(1, sched.store_stages)
                per = (len(stores) + stages - 1) // stages
                for s0 in range(0, len(stores), per):
                    prog.add(Superstep(comm=stores[s0:s0 + per],
                                       label=f"i{om},{on} store"))
            it += 1
    if pending_stores:
        prog.add(Superstep(comm=pending_stores, label="final store drain"))
    return prog
