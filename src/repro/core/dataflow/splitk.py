"""Split-K dataflow (paper §3.3.2, Fig. 6e + Insight 3).

3-D tiling: the logical grid is (gm x gn x gk); the gk tiles sharing an output
tile process disjoint K-slices concurrently (each slice runs a SUMMA schedule
over its own strided mask groups — 'strided broadcast supported by mask-based
multiple addressing'), then partial C tiles are combined with a hardware NoC
reduction to a configurable owner (§3.1.1 reduction policy) which commits the
result to HBM.

The payoff (Insight 3): for irregular shapes, gk > 1 buys gm/gn small enough
that TM/TN stay matrix-engine-friendly (e.g. N=2112 over gn=4 -> TN=528
instead of TN=66 on a 32x32 2-D mapping).

Mesh-execution analogue: `dit_gemm` mode `splitk` (docs/dataflows.md).
"""
from __future__ import annotations

from typing import List

from repro.core.dataflow.common import GridView
from repro.core.ir import DMAOp, MMADOp, MulticastOp, Program, ReduceOp, Superstep
from repro.core.schedule import Schedule
from repro.hw.config import AcceleratorConfig


def _fetch_and_multicast(g: GridView, om: int, on: int, t: int, slot: int) -> List[object]:
    ops: List[object] = []
    for lk in range(g.gk):
        for lm in range(g.gm):
            owner = g.coord(lm, t % g.gn, lk)
            ops.append(DMAOp(owner, "load", "A", g.a_tile(om, lm, t, lk), "A", slot))
            if g.gn > 1:
                ops.append(MulticastOp(owner, g.row_group(lm, lk), "A", slot,
                                       after_dma=True))
        for ln in range(g.gn):
            owner = g.coord(t % g.gm, ln, lk)
            ops.append(DMAOp(owner, "load", "B", g.b_tile(on, ln, t, lk), "B", slot))
            if g.gm > 1:
                ops.append(MulticastOp(owner, g.col_group(ln, lk), "B", slot,
                                       after_dma=True))
    return ops


def _owner_lk(g: GridView, sched: Schedule, lm: int, ln: int) -> int:
    if sched.reduce_owner == "round_robin":
        return (lm * g.gn + ln) % g.gk
    return 0


def build(sched: Schedule, hw: AcceleratorConfig) -> Program:
    if sched.tiling.gk < 2:
        raise ValueError("splitk_summa requires gk >= 2")
    g = GridView(sched, hw)
    db = sched.double_buffer
    prog = g.make_program(g.std_buffers(), name="splitk_summa")

    for om in range(g.iter_m):
        for on in range(g.iter_n):
            if db:
                prog.add(Superstep(comm=_fetch_and_multicast(g, om, on, 0, 0),
                                   label="pro"))
                for t in range(g.n_ksteps):
                    step = Superstep(label=f"k{t}")
                    for lk in range(g.gk):
                        for lm in range(g.gm):
                            for ln in range(g.gn):
                                step.compute.append(MMADOp(
                                    g.coord(lm, ln, lk), "A", t % 2, "B", t % 2,
                                    "C", 0, init=(t == 0), tm=g.tm, tn=g.tn, tk=g.tk))
                    if t + 1 < g.n_ksteps:
                        step.comm.extend(_fetch_and_multicast(g, om, on, t + 1, (t + 1) % 2))
                    prog.add(step)
            else:
                for t in range(g.n_ksteps):
                    prog.add(Superstep(comm=_fetch_and_multicast(g, om, on, t, 0),
                                       label=f"fetch k{t}"))
                    step = Superstep(label=f"k{t}")
                    for lk in range(g.gk):
                        for lm in range(g.gm):
                            for ln in range(g.gn):
                                step.compute.append(MMADOp(
                                    g.coord(lm, ln, lk), "A", 0, "B", 0, "C", 0,
                                    init=(t == 0), tm=g.tm, tn=g.tn, tk=g.tk))
                    prog.add(step)

            # NoC reduction of partial C over each k-group, then owner commits.
            red = Superstep(label="k-reduce")
            for lm in range(g.gm):
                for ln in range(g.gn):
                    owner = g.coord(lm, ln, _owner_lk(g, sched, lm, ln))
                    red.comm.append(ReduceOp(g.k_group(lm, ln), owner, "C", 0))
            prog.add(red)
            stages = max(1, sched.store_stages)
            stores = [DMAOp(g.coord(lm, ln, _owner_lk(g, sched, lm, ln)),
                            "store", "C", g.c_tile(om, on, lm, ln), "C", 0)
                      for lm in range(g.gm) for ln in range(g.gn)]
            per = (len(stores) + stages - 1) // stages
            for s0 in range(0, len(stores), per):
                prog.add(Superstep(comm=stores[s0:s0 + per], label="store"))
    return prog
