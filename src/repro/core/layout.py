"""HBM data layout (paper §3.2).

SoftHier's HBM is a set of distinct per-channel address spaces, so DiT controls
the physical distribution of each matrix explicitly with two parameters:

- **Split scheme** (§3.2.1): partition the M x N matrix into a grid of blocks;
  blocks are the coarsest distribution unit, assigned to channels round-robin.
- **Placement scheme** (§3.2.2): inside one channel, a block is decomposed into
  TM x TN tiles stored contiguously in row-major order (tile sizes come from
  the workload tiling, §3.1).

The functional simulator uses `channel_of_block` / `tile_address` to place and
fetch real data; the cost model uses `channel_traffic` to detect channel
contention (the paper's Insight 1 — a bad layout leaves channels idle while
others are thrashed).

On the TPU target the analogous decisions are (a) the PartitionSpec that
shards an operand over chips (split scheme == which chip's HBM owns a block)
and (b) the BlockSpec tile shape inside a chip (placement scheme == the order
VMEM tiles stream from HBM).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SplitScheme:
    """Partition an (M, N) matrix into a (grid_m x grid_n) grid of blocks."""
    grid_m: int
    grid_n: int

    def block_shape(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        m, n = shape
        if m % self.grid_m or n % self.grid_n:
            raise ValueError(f"matrix {shape} not divisible by split {self}")
        return m // self.grid_m, n // self.grid_n

    def n_blocks(self) -> int:
        return self.grid_m * self.grid_n

    def block_index(self, bi: int, bj: int) -> int:
        return bi * self.grid_n + bj


@dataclasses.dataclass(frozen=True)
class PlacementScheme:
    """Arrange TM x TN tiles of one block contiguously (row-major) in the
    1-D address space of its channel."""
    tm: int
    tn: int

    def tiles_per_block(self, block_shape: Tuple[int, int]) -> Tuple[int, int]:
        bm, bn = block_shape
        if bm % self.tm or bn % self.tn:
            raise ValueError(f"block {block_shape} not divisible by tile ({self.tm},{self.tn})")
        return bm // self.tm, bn // self.tn


@dataclasses.dataclass(frozen=True)
class DataLayout:
    """Complete layout of one matrix across the distributed HBM channels."""
    split: SplitScheme
    placement: PlacementScheme
    n_channels: int
    # round-robin phase: block k lives on channel (k + phase) % n_channels.
    phase: int = 0

    def channel_of_block(self, bi: int, bj: int) -> int:
        return (self.split.block_index(bi, bj) + self.phase) % self.n_channels

    def block_of_tile(self, ti: int, tj: int, shape: Tuple[int, int]) -> Tuple[int, int]:
        """Which block the (ti, tj)-th TM x TN tile falls in."""
        bm, bn = self.split.block_shape(shape)
        return (ti * self.placement.tm) // bm, (tj * self.placement.tn) // bn

    def channel_of_tile(self, ti: int, tj: int, shape: Tuple[int, int]) -> int:
        bi, bj = self.block_of_tile(ti, tj, shape)
        return self.channel_of_block(bi, bj)

    def tile_address(self, ti: int, tj: int, shape: Tuple[int, int],
                     elem_bytes: int) -> Tuple[int, int]:
        """(channel, byte offset) of a tile — the preload-file address map."""
        bm, bn = self.split.block_shape(shape)
        tpb_m, tpb_n = self.placement.tiles_per_block((bm, bn))
        bi, bj = self.block_of_tile(ti, tj, shape)
        li, lj = ti - bi * tpb_m, tj - bj * tpb_n
        tile_bytes = self.placement.tm * self.placement.tn * elem_bytes
        # blocks mapped to the same channel stack up in channel address space.
        blocks_before = self.split.block_index(bi, bj) // self.n_channels
        block_bytes = tpb_m * tpb_n * tile_bytes
        offset = blocks_before * block_bytes + (li * tpb_n + lj) * tile_bytes
        return self.channel_of_block(bi, bj), offset

    # -- contention analysis -------------------------------------------------

    def channel_traffic(self, tile_reads: List[Tuple[int, int]],
                        shape: Tuple[int, int], elem_bytes: int) -> Dict[int, int]:
        """Bytes requested from each channel by a list of tile reads. The cost
        model turns the max/mean imbalance of this histogram into effective-
        bandwidth derating (contended channels serialize)."""
        traffic: Dict[int, int] = {}
        tile_bytes = self.placement.tm * self.placement.tn * elem_bytes
        for (ti, tj) in tile_reads:
            ch = self.channel_of_tile(ti, tj, shape)
            traffic[ch] = traffic.get(ch, 0) + tile_bytes
        return traffic


def base_layout(shape: Tuple[int, int], tm: int, tn: int, n_channels: int) -> DataLayout:
    """The paper's *base* layout: row-major, no distribution — the whole matrix
    is a single block on channel 0 (the Baseline w/o Optimal Layout in Fig. 7a)."""
    return DataLayout(SplitScheme(1, 1), PlacementScheme(tm, tn), n_channels)


def optimal_layout(shape: Tuple[int, int], tm: int, tn: int, n_channels: int) -> DataLayout:
    """Round-robin every tile-granular block over all channels — the 'optimized
    layout' the paper reports: split grid == tile grid so consecutive fetches
    hit distinct channels."""
    m, n = shape
    return DataLayout(SplitScheme(max(1, m // tm), max(1, n // tn)),
                      PlacementScheme(tm, tn), n_channels)


def candidate_layouts(shape: Tuple[int, int], tm: int, tn: int,
                      n_channels: int) -> List[DataLayout]:
    """Layout search space for the autotuner: power-of-2 split grids between
    base (1x1) and tile-granular, all channel phases collapsed to 0 (phase only
    matters when two operands collide — handled at schedule level)."""
    m, n = shape
    max_gm, max_gn = max(1, m // tm), max(1, n // tn)
    cands = []
    gm = 1
    while gm <= max_gm:
        gn = 1
        while gn <= max_gn:
            if m % gm == 0 and n % gn == 0:
                bm, bn = m // gm, n // gn
                if bm % tm == 0 and bn % tn == 0:
                    cands.append(DataLayout(SplitScheme(gm, gn),
                                            PlacementScheme(tm, tn), n_channels))
            gn *= 2
        gm *= 2
    return cands


def pack_preload(matrix: np.ndarray, layout: DataLayout,
                 elem_bytes: int) -> Dict[int, np.ndarray]:
    """Build the preload image: per-channel flat byte arrays with every tile at
    the address `tile_address` reports. This is the 'Preload' workflow stage
    (§2.3) — the simulator initializes its HBM channels from this."""
    m, n = matrix.shape
    tm, tn = layout.placement.tm, layout.placement.tn
    per_channel: Dict[int, bytearray] = {c: bytearray() for c in range(layout.n_channels)}
    # first pass: compute sizes
    sizes: Dict[int, int] = {c: 0 for c in range(layout.n_channels)}
    tile_bytes = tm * tn * elem_bytes
    for ti in range(m // tm):
        for tj in range(n // tn):
            ch, off = layout.tile_address(ti, tj, (m, n), elem_bytes)
            sizes[ch] = max(sizes[ch], off + tile_bytes)
    images = {c: np.zeros(sizes[c], dtype=np.uint8) for c in range(layout.n_channels) if sizes[c]}
    for ti in range(m // tm):
        for tj in range(n // tn):
            ch, off = layout.tile_address(ti, tj, (m, n), elem_bytes)
            tile = np.ascontiguousarray(matrix[ti * tm:(ti + 1) * tm, tj * tn:(tj + 1) * tn])
            images[ch][off:off + tile_bytes] = tile.view(np.uint8).reshape(-1)
    return images


def unpack_preload(images: Dict[int, np.ndarray], layout: DataLayout,
                   shape: Tuple[int, int], dtype: np.dtype) -> np.ndarray:
    """Inverse of pack_preload — used to read C back out of simulated HBM."""
    m, n = shape
    tm, tn = layout.placement.tm, layout.placement.tn
    elem_bytes = np.dtype(dtype).itemsize
    tile_bytes = tm * tn * elem_bytes
    out = np.zeros(shape, dtype=dtype)
    for ti in range(m // tm):
        for tj in range(n // tn):
            ch, off = layout.tile_address(ti, tj, shape, elem_bytes)
            raw = images[ch][off:off + tile_bytes]
            out[ti * tm:(ti + 1) * tm, tj * tn:(tj + 1) * tn] = (
                raw.view(dtype).reshape(tm, tn))
    return out
