"""Distributed `dit_gemm` — the paper's dataflow pattern primitives retargeted
to a JAX device mesh (DESIGN.md §2.2 table).

SoftHier's tile grid becomes the named mesh; its hardware NoC collectives
become `jax.lax` collectives inside `shard_map`:

- **summa** (Fig. 6a): K-panel loop; each step one-hot-psum-broadcasts the A
  panel along the column axis and the B panel along the row axis (a psum of a
  masked operand IS a fabric broadcast from the owner — the mask-based
  multicast of §2.1), then accumulates the local C block.
- **cannon** (Fig. 6b systolic): Cannon's algorithm — initial skew, then
  rotate A west / B north with `ppermute` (nearest-neighbour ICI hops) and
  accumulate. Square meshes.
- **splitk** (Fig. 6e, 1-D): K sharded; local partial GEMM then `psum_scatter`
  (reduction ownership round-robined over the k-group — §3.1.1's reduction
  policy; `psum` keeps a replicated C = the 'first'-owner policy analogue).
- **splitk_summa** (Fig. 6e, 3-D): the schedule's gk k-groups each run SUMMA
  over a (row × col) sub-grid on their K slice, then the partials NoC-reduce
  over a dedicated k sub-axis of the mesh — the tuned (gm × gn × gk) logical
  grid mapped onto a mesh view instead of collapsing to 1-D split-K.
- **hierarchical** (Fig. 6d, SUMMA over systolic): outer SUMMA over inner
  Cannon groups — each physical axis splits into (outer, inner) per
  `Schedule.inner`; owner groups psum-broadcast outer K-panels along the
  outer axes while each inner group contracts its panel systolically.
- **outer_systolic** (Fig. 6c, systolic over SUMMA): the dual composition —
  an outer Cannon ring of inner SUMMA groups. A/B chunks propagate between
  whole tile groups as a global wavefront (`ppermute` ring steps over the
  outer axes, wavefront skew by outer grid index) while each inner group
  runs the shared `_summa_acc` body on its subproblem.
- **allgather** (beyond-paper baseline): gather all panels once, single local
  GEMM. Highest memory, fewest collectives — XLA's default TP pattern.
- **auto**: sharding-constrained einsum; XLA chooses the collective schedule.

Schedule-driven dispatch goes through `repro.core.lower.lower_schedule`,
which resolves the tuned dataflow + logical grid into an `ExecPlan` (mode,
mesh view, kwargs, explicit fallback chain); `dit_gemm` consumes the plan.

All modes are numerically validated against each other on a multi-device CPU
mesh (tests/test_gemm_modes.py, tests/test_lowering.py; subprocess with fake
devices). The panel / skew / rotate loops are `lax.scan` (not `fori_loop`)
so every mode is reverse-differentiable — plan-routed training matmuls
backprop through the collectives.

See docs/dataflows.md for the mode-by-mode collective patterns, divisibility
preconditions, and fallback reasons.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.lower import ExecPlan, lower_schedule
from repro.core.schedule import InnerKernel

# modes dispatchable by name; the plan-only modes (splitk_summa,
# hierarchical, outer_systolic) additionally need a mesh view — see
# lower.EXEC_MODES.
MODES = ("auto", "summa", "cannon", "splitk", "allgather")


def _tile_dot(a: jax.Array, b: jax.Array,
              kernel: Optional[InnerKernel]) -> jax.Array:
    """The per-device contraction every mode body accumulates with.

    `kernel=None` is the legacy path — a bare `jnp.dot` whose inner schedule
    XLA picks. With a plan-resolved `InnerKernel` the contraction routes
    through `kernels.ops.local_matmul` at the planner's block geometry /
    compute dtype (Pallas on TPU, the bitwise-identical jnp oracle on CPU),
    making the intra-device level a tuned schedule dimension rather than a
    compiler default. fp32 out either way.
    """
    if kernel is None:
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    from repro.kernels.ops import local_matmul
    return local_matmul(a, b, kernel)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


@contextlib.contextmanager
def _mode_scope(mode: str):
    """Name every mode's dispatch for both profiling surfaces: the HLO ops
    it traces (`jax.named_scope` — a device profile / xprof segments by
    `dit_gemm.<mode>`) and the host-side trace-time work
    (`jax.profiler.TraceAnnotation`)."""
    name = f"dit_gemm.{mode}"
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


# ---------------------------------------------------------------------------
# SUMMA
# ---------------------------------------------------------------------------

def _summa_acc(a_loc: jax.Array, b_loc: jax.Array, row_axis: str,
               col_axis: str, dm: int, dn: int,
               kernel: Optional[InnerKernel] = None) -> jax.Array:
    """fp32 SUMMA accumulation of the local C block over dm*dn K-panels.

    Runs inside shard_map over (row_axis, col_axis) — which may be sub-axes
    of a larger mesh view, in which case the broadcasts stay within the
    enclosing group (the k-group of splitk_summa).
    """
    panels = dm * dn
    w = a_loc.shape[1] // dm
    i = jax.lax.axis_index(row_axis)
    j = jax.lax.axis_index(col_axis)

    def step(acc, p):
        # A panel p lives on column p // dm at local offset (p % dm) * w
        a_pan = jax.lax.dynamic_slice_in_dim(a_loc, (p % dm) * w, w, axis=1)
        a_pan = jnp.where(j == p // dm, a_pan, jnp.zeros_like(a_pan))
        a_pan = jax.lax.psum(a_pan, col_axis)          # owner broadcast
        # B panel p lives on row p // dn at local offset (p % dn) * w
        b_pan = jax.lax.dynamic_slice_in_dim(b_loc, (p % dn) * w, w, axis=0)
        b_pan = jnp.where(i == p // dn, b_pan, jnp.zeros_like(b_pan))
        b_pan = jax.lax.psum(b_pan, row_axis)          # owner broadcast
        acc = acc + _tile_dot(a_pan, b_pan, kernel)
        return acc, None

    acc = jnp.zeros((a_loc.shape[0], b_loc.shape[1]), dtype=jnp.float32)
    acc, _ = jax.lax.scan(step, acc, jnp.arange(panels))
    return acc


def summa_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
               row_axis: str = "data", col_axis: str = "model",
               kernel: Optional[InnerKernel] = None) -> jax.Array:
    """C[i,j] = sum_p A_panel[i,p] @ B_panel[p,j] with owner broadcasts.

    A is sharded (row_axis, col_axis), B (row_axis, col_axis), C likewise.
    K is split into dm*dn panels so both operands agree on panel width.
    """
    dm, dn = _axis_size(mesh, row_axis), _axis_size(mesh, col_axis)
    m, k = a.shape
    _, n = b.shape
    panels = dm * dn
    if k % panels:
        raise ValueError(f"K={k} must divide by {panels} SUMMA panels")

    def body(a_loc, b_loc):
        return _summa_acc(a_loc, b_loc, row_axis, col_axis,
                          dm, dn, kernel).astype(a_loc.dtype)

    spec2 = P(row_axis, col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec2, spec2),
                     out_specs=spec2, check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# Cannon (systolic)
# ---------------------------------------------------------------------------

def _cannon_acc(a_blk: jax.Array, b_blk: jax.Array, row_axis: str,
                col_axis: str, d: int,
                kernel: Optional[InnerKernel] = None,
                overlap: bool = False) -> jax.Array:
    """fp32 Cannon accumulation on a square d x d (sub-)grid: initial skew,
    then d rotate-and-accumulate steps over `ppermute` rings.

    Like `_summa_acc`, the axes may be inner sub-axes of a mesh view — the
    wavefront then stays within each inner group (hierarchical mode).

    `overlap=True` issues step s+1's ring hops BEFORE consuming step s's
    blocks inside each scan step — numerically identical (the dot still
    reads the pre-rotation blocks), but the collectives are no longer
    data-dependent successors of the contraction, so XLA's async collective
    machinery can hide each `ppermute` behind the tile compute (the paper's
    §3.3.1 DMA/compute double-buffering, at the mesh level).
    """
    left = [(s, (s - 1) % d) for s in range(d)]          # shift along cols
    up = [(s, (s - 1) % d) for s in range(d)]            # shift along rows
    i = jax.lax.axis_index(row_axis)
    j = jax.lax.axis_index(col_axis)

    # initial skew: A block (i, j) -> (i, j - i); B block (i, j) -> (i - j, j).
    # every device executes the same d-1 uniform ppermutes (SPMD-safe)
    # and masks acceptance by its row/column index.
    def skew_a(val, s):
        shifted = jax.lax.ppermute(val, col_axis, left)
        return jnp.where(i > s, shifted, val), None

    def skew_b(val, s):
        shifted = jax.lax.ppermute(val, row_axis, up)
        return jnp.where(j > s, shifted, val), None

    with jax.named_scope("skew"):
        a_cur, _ = jax.lax.scan(skew_a, a_blk, jnp.arange(d - 1))
        b_cur, _ = jax.lax.scan(skew_b, b_blk, jnp.arange(d - 1))

    def step(carry, _):
        a_cur, b_cur, acc = carry
        if overlap:
            # issue next step's hops first; consume the held blocks after
            a_nxt = jax.lax.ppermute(a_cur, col_axis, left)
            b_nxt = jax.lax.ppermute(b_cur, row_axis, up)
            acc = acc + _tile_dot(a_cur, b_cur, kernel)
            return (a_nxt, b_nxt, acc), None
        acc = acc + _tile_dot(a_cur, b_cur, kernel)
        a_cur = jax.lax.ppermute(a_cur, col_axis, left)
        b_cur = jax.lax.ppermute(b_cur, row_axis, up)
        return (a_cur, b_cur, acc), None

    acc = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), dtype=jnp.float32)
    with jax.named_scope("rotate_accumulate"):
        (_, _, acc), _ = jax.lax.scan(step, (a_cur, b_cur, acc), None,
                                      length=d)
    return acc


def cannon_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                row_axis: str = "data", col_axis: str = "model",
                kernel: Optional[InnerKernel] = None,
                overlap: bool = False) -> jax.Array:
    """Systolic GEMM on a square mesh: skew, then rotate-and-accumulate.

    Every transfer is a single nearest-neighbour hop (`ppermute` ring) — the
    wavefront dataflow of Fig. 6b on the ICI torus.
    """
    dm, dn = _axis_size(mesh, row_axis), _axis_size(mesh, col_axis)
    if dm != dn:
        raise ValueError(f"cannon needs a square mesh, got {dm}x{dn}")

    def body(a_loc, b_loc):
        return _cannon_acc(a_loc, b_loc, row_axis, col_axis,
                           dm, kernel, overlap).astype(a_loc.dtype)

    spec2 = P(row_axis, col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec2, spec2),
                     out_specs=spec2, check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# Split-K (1-D and the schedule's 3-D grid)
# ---------------------------------------------------------------------------

def splitk_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                k_axis: str = "model", scatter: bool = True,
                kernel: Optional[InnerKernel] = None) -> jax.Array:
    """K sharded over `k_axis`; local partial GEMM + NoC reduction.

    scatter=True  -> psum_scatter: C row-blocks round-robined over the k-group
                     (the paper's round_robin reduction-owner policy).
    scatter=False -> psum: replicated C (every k-peer ends with the result).
    """
    dk = _axis_size(mesh, k_axis)
    m = a.shape[0]
    if scatter and m % dk:
        raise ValueError(f"M={m} must divide by k-axis size {dk} for scatter")

    def body(a_loc, b_loc):
        part = _tile_dot(a_loc, b_loc, kernel)
        if scatter:
            out = jax.lax.psum_scatter(part, k_axis, scatter_dimension=0,
                                       tiled=True)
        else:
            out = jax.lax.psum(part, k_axis)
        return out.astype(a_loc.dtype)

    in_specs = (P(None, k_axis), P(k_axis, None))
    out_specs = P(k_axis, None) if scatter else P(None, None)
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)(a, b)


def splitk_summa_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                      row_axis: str = "data", col_axis: str = "model",
                      k_axis: str = "splitk",
                      scatter: bool = True,
                      kernel: Optional[InnerKernel] = None) -> jax.Array:
    """3-D split-K on a (row × col × k) mesh view: each of the gk k-groups
    runs SUMMA over its (row × col) sub-grid on a K/gk slice, then partials
    reduce over the k sub-axis.

    A is sharded (m: row, k: k-major/col-minor), B (k: k-major/row-minor,
    n: col) — each k-group holds a contiguous K slice laid out exactly as
    plain SUMMA expects. scatter=True round-robins C row-blocks over the
    k-group (out spec P((row, k), col)); scatter=False psums to a C
    replicated over k.
    """
    rm, rn = _axis_size(mesh, row_axis), _axis_size(mesh, col_axis)
    gk = _axis_size(mesh, k_axis)
    m, k = a.shape
    if k % (gk * rm * rn):
        raise ValueError(f"K={k} must divide by gk*rm*rn={gk * rm * rn}")
    if scatter and m % (rm * gk):
        raise ValueError(f"M={m} must divide by rm*gk={rm * gk} for scatter")

    def body(a_loc, b_loc):
        acc = _summa_acc(a_loc, b_loc, row_axis, col_axis, rm, rn, kernel)
        if scatter:
            out = jax.lax.psum_scatter(acc, k_axis, scatter_dimension=0,
                                       tiled=True)
        else:
            out = jax.lax.psum(acc, k_axis)
        return out.astype(a_loc.dtype)

    in_specs = (P(row_axis, (k_axis, col_axis)), P((k_axis, row_axis), col_axis))
    out_specs = (P((row_axis, k_axis), col_axis) if scatter
                 else P(row_axis, col_axis))
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# Hierarchical: outer SUMMA over inner Cannon groups
# ---------------------------------------------------------------------------

def hierarchical_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                      row_axis: str = "data", col_axis: str = "model",
                      inner_row: str = "data_in",
                      inner_col: str = "model_in",
                      kernel: Optional[InnerKernel] = None,
                      overlap: bool = False) -> jax.Array:
    """Hierarchical dataflow on an (outer_row × inner_row × outer_col ×
    inner_col) mesh view — the mesh analogue of the paper's Fig. 6d
    (SUMMA over systolic): the outer (Om × On) grid of inner (ih × ih)
    groups runs SUMMA at the group level while each group contracts its
    K-panel with Cannon's wavefront. Fig. 6c's dual composition is
    `outer_systolic_gemm` below.

    Per outer panel p (of Om*On): the owner outer-column psum-broadcasts the
    A panel along `col_axis`, the owner outer-row the B panel along
    `row_axis` (masked-psum = the mask-based multicast of §2.1, here between
    whole tile groups); each device slices its Cannon block from the
    group-gathered panel, and the inner group accumulates it systolically.
    """
    om, ih = _axis_size(mesh, row_axis), _axis_size(mesh, inner_row)
    on, iw = _axis_size(mesh, col_axis), _axis_size(mesh, inner_col)
    if ih != iw:
        raise ValueError(f"hierarchical needs square inner groups, got {ih}x{iw}")
    m, k = a.shape
    _, n = b.shape
    if k % (om * on * ih):
        raise ValueError(f"K={k} must divide by Om*On*ih={om * on * ih}")
    wo = k // (om * on)          # outer K-panel width (per group)
    wk = wo // ih                # inner Cannon block width
    panels = om * on

    def body(a_loc, b_loc):
        oi = jax.lax.axis_index(row_axis)
        oj = jax.lax.axis_index(col_axis)
        li = jax.lax.axis_index(inner_row)
        lj = jax.lax.axis_index(inner_col)
        # reassemble each group's contiguous K range so any outer panel can
        # be sliced uniformly (alignment-free at the cost of one gather)
        a_g = jax.lax.all_gather(a_loc, inner_col, axis=1, tiled=True)
        b_g = jax.lax.all_gather(b_loc, inner_row, axis=0, tiled=True)

        def outer_step(acc, p):
            # A panel p: owner outer-col p // om, group-local offset
            # (p % om) * wo; this device's Cannon block is k sub-chunk lj
            a_pan = jax.lax.dynamic_slice_in_dim(
                a_g, (p % om) * wo + lj * wk, wk, axis=1)
            a_pan = jnp.where(oj == p // om, a_pan, jnp.zeros_like(a_pan))
            a_pan = jax.lax.psum(a_pan, col_axis)       # group broadcast
            # B panel p: owner outer-row p // on; Cannon block = sub-chunk li
            b_pan = jax.lax.dynamic_slice_in_dim(
                b_g, (p % on) * wo + li * wk, wk, axis=0)
            b_pan = jnp.where(oi == p // on, b_pan, jnp.zeros_like(b_pan))
            b_pan = jax.lax.psum(b_pan, row_axis)       # group broadcast
            acc = acc + _cannon_acc(a_pan, b_pan, inner_row, inner_col, ih,
                                    kernel, overlap)
            return acc, None

        acc = jnp.zeros((a_loc.shape[0], b_loc.shape[1]), dtype=jnp.float32)
        with jax.named_scope("outer_panels"):
            acc, _ = jax.lax.scan(outer_step, acc, jnp.arange(panels))
        return acc.astype(a_loc.dtype)

    spec = P((row_axis, inner_row), (col_axis, inner_col))
    return shard_map(body, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
                     check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# Outer-systolic: outer Cannon ring of inner SUMMA groups (Fig. 6c)
# ---------------------------------------------------------------------------

def outer_systolic_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                        row_axis: str = "data", col_axis: str = "model",
                        inner_row: str = "data_in",
                        inner_col: str = "model_in",
                        kernel: Optional[InnerKernel] = None,
                        overlap: bool = False) -> jax.Array:
    """Fig. 6c's systolic-over-SUMMA composition on an (outer_row ×
    inner_row × outer_col × inner_col) mesh view: Cannon's wavefront runs at
    the *group* level while each inner (ih × ih) group contracts its current
    K-chunk with SUMMA.

    K splits into D = Om (== On) outer chunks, one per group column. After
    the initial group-level skew (A group-block (oi, oj) → (oi, oj − oi);
    B → (oi − oj, oj)), every outer step contracts the held chunk through
    the shared `_summa_acc` body inside the group, then rotates the whole
    A chunk one group west and the B chunk one group north — each rotation
    is a single `ppermute` ring step over an *outer* axis, so A/B chunks
    propagate between tile groups as a global wavefront (the paper's
    group-to-group P2P of the hold buffers) with no broadcast at the outer
    level at all.

    Needs a square outer grid (the ring) and square inner groups (the inner
    SUMMA panel algebra): `lower_schedule` falls back to `hierarchical`
    otherwise, with the reason recorded.
    """
    om, ih = _axis_size(mesh, row_axis), _axis_size(mesh, inner_row)
    on, iw = _axis_size(mesh, col_axis), _axis_size(mesh, inner_col)
    if ih != iw:
        raise ValueError(f"outer_systolic needs square inner groups, "
                         f"got {ih}x{iw}")
    if om != on:
        raise ValueError(f"outer_systolic needs a square outer grid, "
                         f"got {om}x{on}")
    m, k = a.shape
    if k % (om * ih * ih):
        raise ValueError(f"K={k} must divide by Om*ih^2={om * ih * ih}")
    d = om

    def body(a_loc, b_loc):
        oi = jax.lax.axis_index(row_axis)
        oj = jax.lax.axis_index(col_axis)
        ring = [(s, (s - 1) % d) for s in range(d)]

        # group-level skew: like `_cannon_acc`'s, but masked by the OUTER
        # grid index — every device in outer row oi applies oi ring hops
        def skew_a(val, s):
            shifted = jax.lax.ppermute(val, col_axis, ring)
            return jnp.where(oi > s, shifted, val), None

        def skew_b(val, s):
            shifted = jax.lax.ppermute(val, row_axis, ring)
            return jnp.where(oj > s, shifted, val), None

        with jax.named_scope("outer_skew"):
            a_cur, _ = jax.lax.scan(skew_a, a_loc, jnp.arange(d - 1))
            b_cur, _ = jax.lax.scan(skew_b, b_loc, jnp.arange(d - 1))

        def outer_step(carry, _):
            a_cur, b_cur, acc = carry
            if overlap:
                # next chunk's group-to-group hops issue before this chunk
                # is consumed — the outer ring hides behind inner compute
                a_nxt = jax.lax.ppermute(a_cur, col_axis, ring)
                b_nxt = jax.lax.ppermute(b_cur, row_axis, ring)
                acc = acc + _summa_acc(a_cur, b_cur, inner_row, inner_col,
                                       ih, ih, kernel)
                return (a_nxt, b_nxt, acc), None
            acc = acc + _summa_acc(a_cur, b_cur, inner_row, inner_col,
                                   ih, ih, kernel)
            a_cur = jax.lax.ppermute(a_cur, col_axis, ring)   # chunk west
            b_cur = jax.lax.ppermute(b_cur, row_axis, ring)   # chunk north
            return (a_cur, b_cur, acc), None

        acc = jnp.zeros((a_loc.shape[0], b_loc.shape[1]), dtype=jnp.float32)
        with jax.named_scope("outer_steps"):
            (_, _, acc), _ = jax.lax.scan(outer_step, (a_cur, b_cur, acc),
                                          None, length=d)
        return acc.astype(a_loc.dtype)

    spec = P((row_axis, inner_row), (col_axis, inner_col))
    return shard_map(body, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
                     check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# All-gather baseline + auto
# ---------------------------------------------------------------------------

def allgather_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                   row_axis: str = "data", col_axis: str = "model",
                   kernel: Optional[InnerKernel] = None) -> jax.Array:
    """Gather A's panels along cols / B's along rows once, then one local GEMM."""
    def body(a_loc, b_loc):
        a_full = jax.lax.all_gather(a_loc, col_axis, axis=1, tiled=True)
        b_full = jax.lax.all_gather(b_loc, row_axis, axis=0, tiled=True)
        return _tile_dot(a_full, b_full, kernel).astype(a_loc.dtype)

    spec2 = P(row_axis, col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec2, spec2),
                     out_specs=spec2, check_rep=False)(a, b)


def auto_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
              row_axis: str = "data", col_axis: str = "model") -> jax.Array:
    """Sharding-constrained einsum: DiT picks the layout (split scheme), XLA
    picks the collective schedule."""
    spec2 = P(row_axis, col_axis)
    a = jax.lax.with_sharding_constraint(a, NamedSharding(mesh, spec2))
    b = jax.lax.with_sharding_constraint(b, NamedSharding(mesh, spec2))
    out = jnp.einsum("mk,kn->mn", a, b, preferred_element_type=jnp.float32)
    out = jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec2))
    return out.astype(a.dtype)


# ---------------------------------------------------------------------------
# ExecPlan dispatch
# ---------------------------------------------------------------------------

def exec_plan_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                   exec_plan: ExecPlan) -> jax.Array:
    """Run one 2-D GEMM exactly as a resolved `ExecPlan` prescribes."""
    ax = exec_plan.axes
    emesh = (exec_plan.view.materialize(mesh) if exec_plan.view is not None
             else mesh)
    mode = exec_plan.mode
    ik = exec_plan.inner_kernel
    ov = exec_plan.overlap
    with _mode_scope(mode):
        if mode == "auto":
            return auto_gemm(a, b, mesh, ax["row"], ax["col"])
        if mode == "summa":
            return summa_gemm(a, b, emesh, ax["row"], ax["col"], kernel=ik)
        if mode == "cannon":
            return cannon_gemm(a, b, emesh, ax["row"], ax["col"],
                               kernel=ik, overlap=ov)
        if mode == "allgather":
            return allgather_gemm(a, b, emesh, ax["row"], ax["col"],
                                  kernel=ik)
        if mode == "splitk":
            return splitk_gemm(a, b, emesh, k_axis=ax["k"],
                               scatter=exec_plan.kwargs.get("scatter", True),
                               kernel=ik)
        if mode == "splitk_summa":
            return splitk_summa_gemm(
                a, b, emesh, ax["row"], ax["col"], ax["k"],
                scatter=exec_plan.kwargs.get("scatter", True), kernel=ik)
        if mode == "hierarchical":
            return hierarchical_gemm(a, b, emesh, ax["row"], ax["col"],
                                     ax["inner_row"], ax["inner_col"],
                                     kernel=ik, overlap=ov)
        if mode == "outer_systolic":
            return outer_systolic_gemm(a, b, emesh, ax["row"], ax["col"],
                                       ax["inner_row"], ax["inner_col"],
                                       kernel=ik, overlap=ov)
    raise KeyError(f"ExecPlan resolved to unknown mode {mode!r}")


def dit_gemm(a: jax.Array, b: jax.Array, mesh: Mesh, mode: str = "auto",
             row_axis: str = "data", col_axis: str = "model",
             plan=None, planner=None, exec_plan: Optional[ExecPlan] = None,
             **kw) -> jax.Array:
    """Dispatch on the deployment schedule's dataflow pattern.

    Three override layers, strongest first:

    - `exec_plan` (a `repro.core.lower.ExecPlan`): a pre-resolved lowering —
      dispatched verbatim (this is how `models.matmul.pmm` calls after
      recording the plan's fallback chain in its stats).
    - `plan` (a `repro.deploy.DeploymentPlan` or a bare `Schedule`) or
      `planner` (a `repro.deploy.Planner`, consulted — and warmed — per
      shape): the tuned schedule is lowered here via `lower_schedule`
      against the actual operand shapes; caller `**kw` dispatch knobs
      (currently `scatter`) merge into the mode kwargs *before* legality,
      so validation sees exactly what dispatch will use — geometry knobs
      are the schedule's alone.
    - `mode` + `**kw`: direct dispatch of one of `MODES`.

    `a` may carry leading batch/seq dims (B, S, K): they flatten into M for
    both the planner's GEMMShape and the shard_map dispatch, and the result
    is reshaped back to (B, S, N). `b` must be 2-D (K, N).
    """
    if b.ndim != 2:
        raise ValueError(f"dit_gemm expects a 2-D weight, got {b.shape}")
    lead = a.shape[:-1]
    if a.ndim != 2:
        a = a.reshape(-1, a.shape[-1])
    if planner is not None and plan is None:
        from repro.core.schedule import GEMMShape
        plan = planner.plan(GEMMShape(a.shape[0], b.shape[1], a.shape[1]))
    if exec_plan is None and plan is not None:
        sched = getattr(plan, "schedule", plan)
        exec_plan = lower_schedule(sched, mesh, row_axis, col_axis,
                                   shape=(a.shape[0], b.shape[1], a.shape[1]),
                                   overrides=kw)
    if exec_plan is not None:
        out = exec_plan_gemm(a, b, mesh, exec_plan)
    elif mode not in MODES:
        raise KeyError(f"unknown mode {mode!r}; have {MODES}")
    else:
        with _mode_scope(mode):
            if mode == "auto":
                out = auto_gemm(a, b, mesh, row_axis, col_axis)
            elif mode == "summa":
                out = summa_gemm(a, b, mesh, row_axis, col_axis)
            elif mode == "cannon":
                out = cannon_gemm(a, b, mesh, row_axis, col_axis)
            elif mode == "splitk":
                out = splitk_gemm(a, b, mesh,
                                  k_axis=kw.get("k_axis", col_axis),
                                  scatter=kw.get("scatter", True))
            else:
                out = allgather_gemm(a, b, mesh, row_axis, col_axis)
    if len(lead) != 1:
        out = out.reshape(*lead, b.shape[1])
    return out
