"""Distributed `dit_gemm` — the paper's dataflow pattern primitives retargeted
to a JAX device mesh (DESIGN.md §2.2 table).

SoftHier's tile grid becomes the named mesh; its hardware NoC collectives
become `jax.lax` collectives inside `shard_map`:

- **summa** (Fig. 6a): K-panel loop; each step one-hot-psum-broadcasts the A
  panel along the column axis and the B panel along the row axis (a psum of a
  masked operand IS a fabric broadcast from the owner — the mask-based
  multicast of §2.1), then accumulates the local C block.
- **cannon** (Fig. 6b systolic): Cannon's algorithm — initial skew, then
  rotate A west / B north with `ppermute` (nearest-neighbour ICI hops) and
  accumulate. Square meshes.
- **splitk** (Fig. 6e): K sharded; local partial GEMM then `psum_scatter`
  (reduction ownership round-robined over the k-group — §3.1.1's reduction
  policy; `psum` keeps a replicated C = the 'first'-owner policy analogue).
- **allgather** (beyond-paper baseline): gather all panels once, single local
  GEMM. Highest memory, fewest collectives — XLA's default TP pattern.
- **auto**: sharding-constrained einsum; XLA chooses the collective schedule.

All modes are numerically validated against each other on a multi-device CPU
mesh (tests/test_gemm_modes.py, subprocess with fake devices). The panel /
skew / rotate loops are `lax.scan` (not `fori_loop`) so every mode is
reverse-differentiable — plan-routed training matmuls backprop through the
collectives.

See docs/dataflows.md for the mode-by-mode collective patterns, divisibility
preconditions, and fallback behavior.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

MODES = ("auto", "summa", "cannon", "splitk", "allgather")


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


# ---------------------------------------------------------------------------
# SUMMA
# ---------------------------------------------------------------------------

def summa_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
               row_axis: str = "data", col_axis: str = "model") -> jax.Array:
    """C[i,j] = sum_p A_panel[i,p] @ B_panel[p,j] with owner broadcasts.

    A is sharded (row_axis, col_axis), B (row_axis, col_axis), C likewise.
    K is split into dm*dn panels so both operands agree on panel width.
    """
    dm, dn = _axis_size(mesh, row_axis), _axis_size(mesh, col_axis)
    m, k = a.shape
    _, n = b.shape
    panels = dm * dn
    if k % panels:
        raise ValueError(f"K={k} must divide by {panels} SUMMA panels")
    w = k // panels

    def body(a_loc, b_loc):
        # a_loc: (m/dm, k/dn) holds dm panels; b_loc: (k/dm, n/dn) holds dn.
        i = jax.lax.axis_index(row_axis)
        j = jax.lax.axis_index(col_axis)

        def step(acc, p):
            # A panel p lives on column p // dm at local offset (p % dm) * w
            a_pan = jax.lax.dynamic_slice_in_dim(a_loc, (p % dm) * w, w, axis=1)
            a_pan = jnp.where(j == p // dm, a_pan, jnp.zeros_like(a_pan))
            a_pan = jax.lax.psum(a_pan, col_axis)          # owner broadcast
            # B panel p lives on row p // dn at local offset (p % dn) * w
            b_pan = jax.lax.dynamic_slice_in_dim(b_loc, (p % dn) * w, w, axis=0)
            b_pan = jnp.where(i == p // dn, b_pan, jnp.zeros_like(b_pan))
            b_pan = jax.lax.psum(b_pan, row_axis)          # owner broadcast
            acc = acc + jnp.dot(a_pan, b_pan, preferred_element_type=jnp.float32)
            return acc, None

        acc = jnp.zeros((a_loc.shape[0], b_loc.shape[1]), dtype=jnp.float32)
        acc, _ = jax.lax.scan(step, acc, jnp.arange(panels))
        return acc.astype(a_loc.dtype)

    spec2 = P(row_axis, col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec2, spec2),
                     out_specs=spec2, check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# Cannon (systolic)
# ---------------------------------------------------------------------------

def cannon_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                row_axis: str = "data", col_axis: str = "model") -> jax.Array:
    """Systolic GEMM on a square mesh: skew, then rotate-and-accumulate.

    Every transfer is a single nearest-neighbour hop (`ppermute` ring) — the
    wavefront dataflow of Fig. 6b on the ICI torus.
    """
    dm, dn = _axis_size(mesh, row_axis), _axis_size(mesh, col_axis)
    if dm != dn:
        raise ValueError(f"cannon needs a square mesh, got {dm}x{dn}")
    nsteps = dm

    left = [(s, (s - 1) % dn) for s in range(dn)]        # shift along cols
    up = [(s, (s - 1) % dm) for s in range(dm)]          # shift along rows

    def body(a_loc, b_loc):
        i = jax.lax.axis_index(row_axis)
        j = jax.lax.axis_index(col_axis)

        # initial skew: A block (i, j) -> (i, j - i); B block (i, j) -> (i - j, j).
        # every device executes the same dm-1 uniform ppermutes (SPMD-safe)
        # and masks acceptance by its row/column index.
        def skew_a(val, s):
            shifted = jax.lax.ppermute(val, col_axis, left)
            return jnp.where(i > s, shifted, val), None

        def skew_b(val, s):
            shifted = jax.lax.ppermute(val, row_axis, up)
            return jnp.where(j > s, shifted, val), None

        a_cur, _ = jax.lax.scan(skew_a, a_loc, jnp.arange(nsteps - 1))
        b_cur, _ = jax.lax.scan(skew_b, b_loc, jnp.arange(nsteps - 1))

        def step(carry, _):
            a_cur, b_cur, acc = carry
            acc = acc + jnp.dot(a_cur, b_cur, preferred_element_type=jnp.float32)
            a_cur = jax.lax.ppermute(a_cur, col_axis, left)
            b_cur = jax.lax.ppermute(b_cur, row_axis, up)
            return (a_cur, b_cur, acc), None

        acc = jnp.zeros((a_loc.shape[0], b_loc.shape[1]), dtype=jnp.float32)
        (_, _, acc), _ = jax.lax.scan(step, (a_cur, b_cur, acc), None,
                                      length=nsteps)
        return acc.astype(a_loc.dtype)

    spec2 = P(row_axis, col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec2, spec2),
                     out_specs=spec2, check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# Split-K
# ---------------------------------------------------------------------------

def splitk_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                k_axis: str = "model", scatter: bool = True) -> jax.Array:
    """K sharded over `k_axis`; local partial GEMM + NoC reduction.

    scatter=True  -> psum_scatter: C row-blocks round-robined over the k-group
                     (the paper's round_robin reduction-owner policy).
    scatter=False -> psum: replicated C (every k-peer ends with the result).
    """
    dk = _axis_size(mesh, k_axis)
    m = a.shape[0]
    if scatter and m % dk:
        raise ValueError(f"M={m} must divide by k-axis size {dk} for scatter")

    def body(a_loc, b_loc):
        part = jnp.dot(a_loc, b_loc, preferred_element_type=jnp.float32)
        if scatter:
            out = jax.lax.psum_scatter(part, k_axis, scatter_dimension=0,
                                       tiled=True)
        else:
            out = jax.lax.psum(part, k_axis)
        return out.astype(a_loc.dtype)

    in_specs = (P(None, k_axis), P(k_axis, None))
    out_specs = P(k_axis, None) if scatter else P(None, None)
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)(a, b)


# ---------------------------------------------------------------------------
# All-gather baseline + auto
# ---------------------------------------------------------------------------

def allgather_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
                   row_axis: str = "data", col_axis: str = "model") -> jax.Array:
    """Gather A's panels along cols / B's along rows once, then one local GEMM."""
    def body(a_loc, b_loc):
        a_full = jax.lax.all_gather(a_loc, col_axis, axis=1, tiled=True)
        b_full = jax.lax.all_gather(b_loc, row_axis, axis=0, tiled=True)
        return jnp.dot(a_full, b_full,
                       preferred_element_type=jnp.float32).astype(a_loc.dtype)

    spec2 = P(row_axis, col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec2, spec2),
                     out_specs=spec2, check_rep=False)(a, b)


def auto_gemm(a: jax.Array, b: jax.Array, mesh: Mesh,
              row_axis: str = "data", col_axis: str = "model") -> jax.Array:
    """Sharding-constrained einsum: DiT picks the layout (split scheme), XLA
    picks the collective schedule."""
    spec2 = P(row_axis, col_axis)
    a = jax.lax.with_sharding_constraint(a, NamedSharding(mesh, spec2))
    b = jax.lax.with_sharding_constraint(b, NamedSharding(mesh, spec2))
    out = jnp.einsum("mk,kn->mn", a, b, preferred_element_type=jnp.float32)
    out = jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec2))
    return out.astype(a.dtype)


def mode_from_schedule(schedule, mesh: Mesh, row_axis: str = "data",
                       col_axis: str = "model") -> Tuple[str, dict]:
    """Map a tuned `Schedule`'s dataflow onto a mesh dispatch (mode, kwargs).

    The SoftHier dataflow names translate to their shard_map analogues:
    splitk_summa -> splitk (scatter iff the schedule's reduction owner is
    round-robined), systolic -> cannon (square meshes only; rectangular
    meshes fall back to summa, the paper's default), baseline -> allgather,
    everything summa-shaped -> summa. `schedule` is duck-typed (dataflow +
    reduce_owner), so both core Schedules and deserialized plans work.
    """
    df = getattr(schedule, "dataflow", "summa")
    kw: dict = {}
    if df == "splitk_summa":
        kw["k_axis"] = col_axis
        kw["scatter"] = getattr(schedule, "reduce_owner", "") == "round_robin"
        return "splitk", kw
    if df == "systolic":
        if _axis_size(mesh, row_axis) == _axis_size(mesh, col_axis):
            return "cannon", kw
        return "summa", kw
    if df == "baseline":
        return "allgather", kw
    return "summa", kw


def _mode_divisible(mode: str, m: int, n: int, k: int, mesh: Mesh,
                    row_axis: str, col_axis: str, k_axis: str) -> bool:
    """Whether `mode`'s shard_map specs legally tile (m, n, k) on `mesh`."""
    dm, dn = _axis_size(mesh, row_axis), _axis_size(mesh, col_axis)
    if mode == "summa":
        return m % dm == 0 and n % dn == 0 and k % (dm * dn) == 0
    if mode in ("cannon", "allgather"):
        return m % dm == 0 and n % dn == 0 and k % dm == 0 and k % dn == 0
    if mode == "splitk":
        return k % _axis_size(mesh, k_axis) == 0
    return True                                     # auto shards anything


def dit_gemm(a: jax.Array, b: jax.Array, mesh: Mesh, mode: str = "auto",
             row_axis: str = "data", col_axis: str = "model",
             plan=None, planner=None, **kw) -> jax.Array:
    """Dispatch on the deployment schedule's dataflow pattern.

    `plan` (a `repro.deploy.DeploymentPlan` or a bare `Schedule`) or
    `planner` (a `repro.deploy.Planner`, consulted — and warmed — per shape)
    overrides `mode`: the tuned dataflow decides the collective pattern
    instead of the hardcoded default.

    `a` may carry leading batch/seq dims (B, S, K): they flatten into M for
    both the planner's GEMMShape and the shard_map dispatch, and the result
    is reshaped back to (B, S, N). `b` must be 2-D (K, N).
    """
    if b.ndim != 2:
        raise ValueError(f"dit_gemm expects a 2-D weight, got {b.shape}")
    lead = a.shape[:-1]
    if a.ndim != 2:
        a = a.reshape(-1, a.shape[-1])
    if planner is not None and plan is None:
        from repro.core.schedule import GEMMShape
        plan = planner.plan(GEMMShape(a.shape[0], b.shape[1], a.shape[1]))
    if plan is not None:
        sched = getattr(plan, "schedule", plan)
        mode, plan_kw = mode_from_schedule(sched, mesh, row_axis, col_axis)
        kw = {**plan_kw, **kw}      # merge BEFORE validating: the legality
        # checks below must see the same values dispatch will use, caller
        # overrides included.
        if mode == "splitk" and kw.get("scatter"):
            # psum_scatter needs M divisible by the k-group; degrade to the
            # replicated-C reduction ('first'-owner policy) when it isn't.
            if a.shape[0] % _axis_size(mesh, kw["k_axis"]):
                kw["scatter"] = False
        if not _mode_divisible(mode, a.shape[0], b.shape[1], a.shape[1],
                               mesh, row_axis, col_axis,
                               kw.get("k_axis", col_axis)):
            # the tuned grid doesn't legally shard these arrays on this
            # mesh (e.g. a SoftHier plan transferred to a mismatched pod
            # view) — let XLA place the collectives rather than crash.
            mode, kw = "auto", {}
    if mode == "auto":
        out = auto_gemm(a, b, mesh, row_axis, col_axis)
    elif mode == "summa":
        out = summa_gemm(a, b, mesh, row_axis, col_axis)
    elif mode == "cannon":
        out = cannon_gemm(a, b, mesh, row_axis, col_axis)
    elif mode == "splitk":
        out = splitk_gemm(a, b, mesh, k_axis=kw.get("k_axis", col_axis),
                          scatter=kw.get("scatter", True))
    elif mode == "allgather":
        out = allgather_gemm(a, b, mesh, row_axis, col_axis)
    else:
        raise KeyError(f"unknown mode {mode!r}; have {MODES}")
    if len(lead) != 1:
        out = out.reshape(*lead, b.shape[1])
    return out
