"""Faithful schedule→mesh lowering (paper §3 applied to the TPU target).

`lower_schedule(schedule, mesh, row_axis, col_axis) -> ExecPlan` resolves a
tuned `Schedule` into the exact collective program the mesh will execute —
*before* dispatch, with every degradation recorded. The tuned deployment
schedule IS the program: the logical (gm × gn × gk) grid, the hierarchical
inner-group shape, and the reduction-owner policy all survive to execution
instead of collapsing onto whatever 2-D pattern happens to fit.

Three layers of resolution:

1. **Dataflow mapping** — each of the six `DATAFLOWS` has an explicit
   lowering (no silent default branch): `summa` → `summa`, `systolic` →
   `cannon`, `baseline` → `allgather`, `splitk_summa` → the 3-D
   `splitk_summa` mode, and the two hierarchical compositions resolve to
   *distinct* modes: `summa_over_systolic` (Fig. 6d) → `hierarchical`
   (outer SUMMA over inner Cannon groups) and `systolic_over_summa`
   (Fig. 6c) → `outer_systolic` (an outer Cannon ring of inner SUMMA
   groups — A/B chunks propagate between tile groups as a global
   wavefront over `ppermute` rings). Fig. 6c needs a square outer grid of
   at least 2×2 for its ring; otherwise it falls back to `hierarchical`
   with the reason recorded (`non_square_outer` / `outer_ring_too_small`).
2. **Mesh-view construction** — when a schedule needs more grid axes than
   the physical mesh exposes, `MeshView` describes sub-axis splits of the
   physical axes: a gk>1 split-K schedule factors gk out of the row or
   column axis (k-groups stay physically adjacent), so a 2×2×2 grid runs as
   true 3-D split-K on an 8-device mesh instead of collapsing to 1-D;
   hierarchical schedules split both axes into (outer, inner) per
   `Schedule.inner`. The view is materialized into a real `jax` Mesh only
   at dispatch time, so lowering itself needs no devices (unit-testable
   with a bare namespace exposing `.shape`).
3. **Legality** — the chosen mode's divisibility preconditions are checked
   against the *actual* problem shape (not the schedule's tuned shape —
   bucketed transfers serve neighbours). Every miss appends a `Fallback`
   with a machine-readable reason and moves down the chain
   (e.g. `hierarchical → summa → auto`); nothing degrades silently.

`repro.core.gemm.dit_gemm` consumes the ExecPlan; `models.matmul.pmm`
records it in `GemmContext.stats` so launchers report *why* routing
degraded, not just that it did. See docs/dataflows.md for the full
lowering table.

This module is importable without jax (only `MeshView.materialize` touches
it), so the deploy layer and device-free tests can reason about lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.schedule import INNER_VMEM_BUDGET

# -- machine-readable fallback reasons --------------------------------------
# mode changes
NON_SQUARE_SYSTOLIC = "non_square_systolic"   # cannon needs dm == dn -> summa
NON_SQUARE_INNER = "non_square_inner"         # inner group not square -> summa
INNER_GRID_MISMATCH = "inner_grid_mismatch"   # inner group doesn't divide the mesh -> summa
NON_SQUARE_OUTER = "non_square_outer"         # Fig. 6c ring needs Om == On -> hierarchical
OUTER_RING_TOO_SMALL = "outer_ring_too_small"  # Om < 2: no ring to rotate -> hierarchical
GRID_MISMATCH = "grid_mismatch"               # gk factors into neither mesh axis -> 1-D splitk
GK_IS_ONE = "gk_is_one"                       # splitk_summa with gk == 1 IS 2-D summa
UNKNOWN_DATAFLOW = "unknown_dataflow"         # unrecognized name -> summa (paper default)
M_NOT_DIVISIBLE = "m_not_divisible"           # -> auto
N_NOT_DIVISIBLE = "n_not_divisible"           # -> auto
K_NOT_DIVISIBLE = "k_not_divisible"           # -> auto
# kwarg demotion (mode unchanged)
SCATTER_M_INDIVISIBLE = "scatter_m_indivisible"  # psum_scatter -> psum
INNER_KERNEL_TOO_LARGE = "inner_kernel_too_large"  # ik working set > VMEM -> XLA inner
# fused-attention lowering (lower_attention)
ATTN_SEQ_NOT_DIVISIBLE = "attn_seq_not_divisible"    # ring needs sq % dm -> flat_merge
ATTN_KV_NOT_DIVISIBLE = "attn_kv_not_divisible"      # skv % dm -> unfused_attn
ATTN_HEADS_REPLICATED = "attn_heads_replicated"      # h/hkv vs dn -> replicate heads (kwarg demotion)
ATTN_UNKNOWN_COMPOSITION = "attn_unknown_composition"  # unrecognized -> flat_merge

REASONS = (NON_SQUARE_SYSTOLIC, NON_SQUARE_INNER, INNER_GRID_MISMATCH,
           NON_SQUARE_OUTER, OUTER_RING_TOO_SMALL, GRID_MISMATCH, GK_IS_ONE,
           UNKNOWN_DATAFLOW, M_NOT_DIVISIBLE, N_NOT_DIVISIBLE,
           K_NOT_DIVISIBLE, SCATTER_M_INDIVISIBLE, INNER_KERNEL_TOO_LARGE,
           ATTN_SEQ_NOT_DIVISIBLE, ATTN_KV_NOT_DIVISIBLE,
           ATTN_HEADS_REPLICATED, ATTN_UNKNOWN_COMPOSITION)

# modes an ExecPlan can resolve to (superset of gemm.MODES: the 3-D split-K
# and both hierarchical modes need a mesh view, so they are plan-only).
# flat_merge/flat_ring are the fused-attention compositions; unfused_attn is
# attention's explicit degrade target — the legacy projections+chunked_sdpa
# path, always reached WITH a recorded reason (never the silent `auto`).
EXEC_MODES = ("auto", "summa", "cannon", "splitk", "splitk_summa",
              "hierarchical", "outer_systolic", "allgather",
              "flat_merge", "flat_ring", "unfused_attn")

# sub-axis names introduced by mesh views
K_AXIS = "splitk"
INNER_SUFFIX = "_in"


@dataclasses.dataclass(frozen=True)
class Fallback:
    """One recorded degradation step of the lowering chain."""
    reason: str
    from_mode: str
    to_mode: str

    def describe(self) -> str:
        return f"{self.from_mode}->{self.to_mode}[{self.reason}]"


@dataclasses.dataclass(frozen=True)
class MeshView:
    """Sub-axis splits of a physical mesh, materialized at dispatch time.

    `splits` maps a physical axis name to the ordered (name, size) sub-axes
    it splits into (outer-major, so split products preserve device order and
    minor sub-axes stay physically adjacent). Axes not named pass through.
    """
    splits: Tuple[Tuple[str, Tuple[Tuple[str, int], ...]], ...]

    def axis_sizes(self, mesh) -> Dict[str, int]:
        """The viewed mesh's {axis: size} without materializing it."""
        out: Dict[str, int] = {}
        split_map = dict(self.splits)
        for ax in mesh.axis_names:
            if ax in split_map:
                out.update(split_map[ax])
            else:
                out[ax] = mesh.shape[ax]
        return out

    def materialize(self, mesh):
        """Reshape `mesh.devices` into the viewed grid (same device order)."""
        from jax.sharding import Mesh
        split_map = dict(self.splits)
        dims: List[int] = []
        names: List[str] = []
        for ax in mesh.axis_names:
            for name, size in split_map.get(ax, ((ax, mesh.shape[ax]),)):
                names.append(name)
                dims.append(size)
        return Mesh(mesh.devices.reshape(dims), tuple(names))


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """The resolved execution of one GEMM: mode + mesh view + kwargs + the
    fallback chain that produced them.

    `axes` maps roles -> axis names in the (viewed) mesh: always `row` and
    `col`; `k` for the split-K modes; `inner_row`/`inner_col` for
    hierarchical. `kwargs` carries mode knobs (`scatter`). `view` is None
    when the physical mesh is used as-is.
    """
    mode: str
    axes: Mapping[str, str]
    kwargs: Mapping[str, Any]
    view: Optional[MeshView]
    requested: str                      # the schedule's dataflow name
    grid: Tuple[int, int, int]          # the schedule's (gm, gn, gk)
    shape: Tuple[int, int, int]         # the actual (m, n, k) lowered for
    fallbacks: Tuple[Fallback, ...] = ()
    # resolved intra-device level: the schedule's InnerKernel (None -> XLA
    # picks the local GEMM) and whether ring hops overlap tile compute
    inner_kernel: Optional[Any] = None
    overlap: bool = False

    @property
    def degraded(self) -> bool:
        """Did the lowering land on `auto` (XLA places the collectives)?"""
        return any(f.to_mode == "auto" for f in self.fallbacks)

    def reasons(self) -> Tuple[str, ...]:
        return tuple(f.reason for f in self.fallbacks)

    def to_dict(self) -> Dict[str, Any]:
        """JSON-able form for traces / run reports (the mesh view is
        summarized by its axis splits; tuple kwargs become lists)."""
        return {
            "mode": self.mode,
            "requested": self.requested,
            "grid": list(self.grid),
            "shape": list(self.shape),
            "axes": dict(self.axes),
            "kwargs": {k: (list(v) if isinstance(v, tuple) else v)
                       for k, v in self.kwargs.items()},
            "view": ({ax: [list(sub) for sub in subs]
                      for ax, subs in self.view.splits}
                     if self.view is not None else None),
            "degraded": self.degraded,
            "fallbacks": [{"reason": f.reason, "from": f.from_mode,
                           "to": f.to_mode} for f in self.fallbacks],
            "inner_kernel": (self.inner_kernel.to_dict()
                             if self.inner_kernel is not None else None),
            "overlap": self.overlap,
        }

    def describe(self) -> str:
        chain = " ".join(f.describe() for f in self.fallbacks)
        gm, gn, gk = self.grid
        return (f"{self.requested}[{gm}x{gn}x{gk}] -> {self.mode}"
                + (f" ik={self.inner_kernel.describe()}"
                   if self.inner_kernel is not None else "")
                + (" overlap" if self.overlap else "")
                + (f" ({chain})" if chain else ""))


def _shape3(shape) -> Tuple[int, int, int]:
    if shape is None:
        raise ValueError("lower_schedule needs a problem shape: pass shape= "
                         "or a schedule with a .shape")
    if hasattr(shape, "m"):
        return int(shape.m), int(shape.n), int(shape.k)
    m, n, k = shape
    return int(m), int(n), int(k)


def lower_schedule(schedule, mesh, row_axis: str = "data",
                   col_axis: str = "model", shape=None,
                   overrides: Optional[Mapping[str, Any]] = None) -> ExecPlan:
    """Resolve a tuned `Schedule` into an `ExecPlan` for `mesh`.

    `schedule` is duck-typed (dataflow / tiling / inner / reduce_owner /
    shape), so core Schedules and deserialized plans both work; `mesh` only
    needs `.shape[axis]` (a real Mesh is required only to materialize the
    view at dispatch). `shape` is the actual problem (GEMMShape or (m, n, k)
    tuple) — it defaults to the schedule's tuned shape but dispatch must
    pass the real operands' shape, which bucketed serving can differ on.
    `overrides` are caller dispatch knobs (currently only `scatter`) merged
    into the mode kwargs *before* legality, so validation sees exactly what
    dispatch will use (the scatter/M drift bug). Geometry is never
    overridable — the mesh view is the schedule's alone, so a caller knob
    cannot diverge from the view it is validated against.
    """
    df = getattr(schedule, "dataflow", "summa")
    tiling = getattr(schedule, "tiling", None)
    grid = (int(getattr(tiling, "gm", 1)), int(getattr(tiling, "gn", 1)),
            int(getattr(tiling, "gk", 1)))
    m, n, k = _shape3(shape if shape is not None
                      else getattr(schedule, "shape", None))
    dm, dn = int(mesh.shape[row_axis]), int(mesh.shape[col_axis])

    fallbacks: List[Fallback] = []

    def fall(reason: str, from_mode: str, to_mode: str) -> None:
        fallbacks.append(Fallback(reason, from_mode, to_mode))

    axes: Dict[str, str] = {"row": row_axis, "col": col_axis}
    kwargs: Dict[str, Any] = {}
    view: Optional[MeshView] = None
    # the effective 2-D/3-D grid the chosen mode runs on
    rm, rn, gk = dm, dn, 1

    # -- 1. dataflow mapping + mesh-view construction -----------------------
    if df == "baseline":
        mode = "allgather"
    elif df == "summa":
        mode = "summa"
    elif df == "systolic":
        if dm != dn:
            fall(NON_SQUARE_SYSTOLIC, "cannon", "summa")
            mode = "summa"
        else:
            mode = "cannon"
    elif df in ("systolic_over_summa", "summa_over_systolic"):
        # the two compositions resolve to DISTINCT modes: Fig. 6d
        # (summa_over_systolic) -> hierarchical (outer SUMMA over inner
        # Cannon groups); Fig. 6c (systolic_over_summa) -> outer_systolic
        # (outer Cannon ring of inner SUMMA groups)
        ih, iw = getattr(schedule, "inner", (2, 2))
        want = "outer_systolic" if df == "systolic_over_summa" \
            else "hierarchical"
        if ih != iw:
            fall(NON_SQUARE_INNER, want, "summa")
            mode = "summa"
        elif dm % ih or dn % iw:
            fall(INNER_GRID_MISMATCH, want, "summa")
            mode = "summa"
        else:
            mode = want
            om, on = dm // ih, dn // iw
            if mode == "outer_systolic" and om != on:
                # the Fig. 6c wavefront rotates A/B chunks around outer
                # ppermute rings, which needs a square outer grid; the
                # outer-SUMMA composition handles rectangular grids
                fall(NON_SQUARE_OUTER, "outer_systolic", "hierarchical")
                mode = "hierarchical"
            elif mode == "outer_systolic" and om < 2:
                # a single outer group has no ring to rotate chunks around
                fall(OUTER_RING_TOO_SMALL, "outer_systolic", "hierarchical")
                mode = "hierarchical"
            irow, icol = row_axis + INNER_SUFFIX, col_axis + INNER_SUFFIX
            view = MeshView(splits=(
                (row_axis, ((row_axis, dm // ih), (irow, ih))),
                (col_axis, ((col_axis, dn // iw), (icol, iw)))))
            axes.update(inner_row=irow, inner_col=icol)
            kwargs["inner"] = (ih, iw)
    elif df == "splitk_summa":
        gk = grid[2]
        kwargs["scatter"] = getattr(schedule, "reduce_owner", "") == "round_robin"
        if gk <= 1:
            # a 2-D split-K schedule IS summa (one K-slice owns everything)
            fall(GK_IS_ONE, "splitk_summa", "summa")
            mode = "summa"
            kwargs.pop("scatter")
            gk = 1
        elif dn % gk == 0:
            # factor the k sub-axis out of the column axis, k minor so each
            # k-group's devices stay physically adjacent for the reduction
            mode = "splitk_summa"
            rm, rn = dm, dn // gk
            view = MeshView(splits=(
                (col_axis, ((col_axis, rn), (K_AXIS, gk))),))
            axes["k"] = K_AXIS
        elif dm % gk == 0:
            mode = "splitk_summa"
            rm, rn = dm // gk, dn
            view = MeshView(splits=(
                (row_axis, ((row_axis, rm), (K_AXIS, gk))),))
            axes["k"] = K_AXIS
        else:
            # the tuned k-grid factors into neither physical axis: collapse
            # to 1-D split-K over the column axis — recorded, not silent
            fall(GRID_MISMATCH, "splitk_summa", "splitk")
            mode = "splitk"
            axes["k"] = col_axis
    else:
        fall(UNKNOWN_DATAFLOW, df, "summa")
        mode = "summa"

    if overrides:
        kwargs.update({key: val for key, val in overrides.items()
                       if key in ("scatter",)})

    # -- 2. legality against the actual problem shape -----------------------
    reason = None
    if mode == "summa":
        if m % dm:
            reason = M_NOT_DIVISIBLE
        elif n % dn:
            reason = N_NOT_DIVISIBLE
        elif k % (dm * dn):
            reason = K_NOT_DIVISIBLE
    elif mode in ("cannon", "allgather"):
        if m % dm:
            reason = M_NOT_DIVISIBLE
        elif n % dn:
            reason = N_NOT_DIVISIBLE
        elif k % dm or k % dn:
            reason = K_NOT_DIVISIBLE
    elif mode == "splitk":
        dk = dn if axes["k"] == col_axis else dm
        if k % dk:
            reason = K_NOT_DIVISIBLE
        elif kwargs.get("scatter") and m % dk:
            fall(SCATTER_M_INDIVISIBLE, "splitk", "splitk")
            kwargs["scatter"] = False
    elif mode == "splitk_summa":
        if m % rm:
            reason = M_NOT_DIVISIBLE
        elif n % rn:
            reason = N_NOT_DIVISIBLE
        elif k % (gk * rm * rn):
            reason = K_NOT_DIVISIBLE
        elif kwargs.get("scatter") and m % (rm * gk):
            fall(SCATTER_M_INDIVISIBLE, "splitk_summa", "splitk_summa")
            kwargs["scatter"] = False
    elif mode in ("hierarchical", "outer_systolic"):
        ih = kwargs["inner"][0]
        om, on = dm // ih, dn // ih
        # hierarchical: Om*On outer SUMMA panels, each split into ih Cannon
        # chunks. outer_systolic: Om outer ring chunks (Om == On), each
        # contracted by an ih*ih-panel inner SUMMA.
        kdiv = om * ih * ih if mode == "outer_systolic" else om * on * ih
        if m % dm:
            reason = M_NOT_DIVISIBLE
        elif n % dn:
            reason = N_NOT_DIVISIBLE
        elif k % kdiv:
            reason = K_NOT_DIVISIBLE
    if reason is not None:
        fall(reason, mode, "auto")
        mode, view = "auto", None
        axes, kwargs = {"row": row_axis, "col": col_axis}, {}

    # -- 3. intra-device level: inner kernel + ring/compute overlap ----------
    ik = getattr(schedule, "inner_kernel", None)
    ov = bool(getattr(schedule, "overlap", False))
    if mode == "auto":
        # XLA owns the whole einsum — no inner kernel or ring to overlap;
        # the auto fallback reason above already covers the degradation
        ik, ov = None, False
    elif ik is not None and ik.working_set_bytes() > INNER_VMEM_BUDGET:
        # kwarg-style demotion (mode unchanged): drop to the XLA-picked
        # local GEMM rather than dispatch a kernel that cannot fit VMEM
        fall(INNER_KERNEL_TOO_LARGE, mode, mode)
        ik = None

    return ExecPlan(mode=mode, axes=axes, kwargs=kwargs, view=view,
                    requested=df, grid=grid, shape=(m, n, k),
                    fallbacks=tuple(fallbacks), inner_kernel=ik, overlap=ov)


def lower_attention(schedule, mesh, row_axis: str = "data",
                    col_axis: str = "model", shape=None) -> ExecPlan:
    """Resolve an `AttnSchedule` into an `ExecPlan` for `mesh`.

    Mirrors `lower_schedule`'s contract: duck-typed schedule, namespace
    mesh (only `.shape[axis]` needed), legality checked against the ACTUAL
    problem shape, every degradation recorded. The chain is

        flat_ring --attn_seq_not_divisible--> flat_merge
                  --attn_kv_not_divisible--> unfused_attn

    plus the kwarg demotion `attn_heads_replicated` (heads replicate over
    the column axis instead of sharding; the mode stays fused). The degrade
    target is the explicit `unfused_attn` mode — the legacy
    projections+chunked_sdpa path — never the silent `auto`.
    """
    shp = shape if shape is not None else getattr(schedule, "shape", None)
    if shp is None:
        raise ValueError("lower_attention needs a problem shape: pass "
                         "shape= or a schedule with a .shape")
    dm, dn = int(mesh.shape[row_axis]), int(mesh.shape[col_axis])
    fallbacks: List[Fallback] = []

    def fall(reason: str, from_mode: str, to_mode: str) -> None:
        fallbacks.append(Fallback(reason, from_mode, to_mode))

    comp = getattr(schedule, "composition", "merge")
    if comp not in ("merge", "ring"):
        fall(ATTN_UNKNOWN_COMPOSITION, f"flat_{comp}", "flat_merge")
        comp = "merge"
    mode = "flat_ring" if comp == "ring" else "flat_merge"

    # ring additionally shards Q over the row axis (sq blocks rotate
    # against the KV ring); an indivisible sq — decode's sq=1 on any
    # dm > 1 mesh — demotes to the merge composition, not to unfused
    if mode == "flat_ring" and (dm > 1 and shp.sq % dm):
        fall(ATTN_SEQ_NOT_DIVISIBLE, "flat_ring", "flat_merge")
        mode, comp = "flat_merge", "merge"

    axes: Dict[str, str] = {"row": row_axis, "col": col_axis}
    kwargs: Dict[str, Any] = {}

    # both fused compositions shard KV over the row axis
    if shp.skv % dm:
        fall(ATTN_KV_NOT_DIVISIBLE, mode, "unfused_attn")
        mode = "unfused_attn"
        axes, kwargs = {"row": row_axis, "col": col_axis}, {}
    else:
        # head sharding over the column axis: query heads must divide, and
        # KV heads must either divide too or be fully replicable (MQA /
        # MLA-absorbed, hkv == 1). Otherwise replicate heads — a kwarg
        # demotion (recorded, mode unchanged), exactly like scatter->psum.
        head_shard = (dn > 1 and shp.h % dn == 0
                      and (shp.hkv % dn == 0 or shp.hkv == 1))
        if dn > 1 and not head_shard:
            fall(ATTN_HEADS_REPLICATED, mode, mode)
        kwargs = {"composition": comp, "head_shard": head_shard,
                  "kv_chunk": int(getattr(schedule, "kv_chunk", 256))}

    return ExecPlan(mode=mode, axes=axes, kwargs=kwargs, view=None,
                    requested=getattr(schedule, "dataflow", "flat_attention"),
                    grid=(dm, dn, 1), shape=(shp.sq, shp.skv, shp.h),
                    fallbacks=tuple(fallbacks), inner_kernel=None,
                    overlap=False)


def lowering_summary(plans: Sequence[ExecPlan]) -> Dict[str, Any]:
    """Aggregate counters for a batch of ExecPlans (benchmark / report)."""
    modes: Dict[str, int] = {}
    reasons: Dict[str, int] = {}
    silent = 0
    for ep in plans:
        modes[ep.mode] = modes.get(ep.mode, 0) + 1
        for f in ep.fallbacks:
            reasons[f.reason] = reasons.get(f.reason, 0) + 1
        if ep.mode == "auto" and not ep.fallbacks:
            silent += 1
    return {"modes": modes, "degrade_reasons": reasons,
            "degraded": sum(1 for ep in plans if ep.degraded),
            "silent_auto_degrades": silent, "total": len(plans)}
