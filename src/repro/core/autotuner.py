"""Schedule autotuner (paper §4.1.4: 'we iterate through our predefined
schedule candidates, guided by the insights above, to automatically select the
kernel achieving the best performance').

Candidate enumeration walks the deployment-schedule space:
  dataflow pattern x logical grid (gm, gn, gk) [cluster remap + 3-D split-K]
  x K-chunk tk x double-buffering x store stages x data layouts,
pruned by legality (divisibility, L1 capacity) and by the paper's insights
(Insight 2: prefer multicast; Insight 3: 3-D tiling for irregular shapes;
Insight 4: remap for flat GEMM). Each candidate is built into a BSP program
and priced with the SoftHier performance model; the best schedule wins.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Tuple

from repro.core.schedule import (GEMMShape, Schedule, Tiling, build_program,
                                 default_elem_dtype, inner_kernel_candidates)
from repro.hw.config import AcceleratorConfig
from repro.sim.calibrate import is_trusted as _trusted
from repro.sim.calibrate import ranking_cost
from repro.sim.perf import PerfReport, estimate, estimate_sweep

# The paper's search space (§4.1.4). The hierarchical compositions join it
# ONLY under a trusted (fit_ok) measured calibration — their simulated win
# must be backed by the machine before the default search may pick them
# (ROADMAP: "enumerate the hierarchical compositions in the DEFAULT tuner
# search space once the cost model is validated against measurements").
DEFAULT_DATAFLOWS = ("summa", "splitk_summa", "systolic", "baseline")
CALIBRATED_DATAFLOWS = ("systolic_over_summa", "summa_over_systolic")


def default_dataflows(calibration=None) -> List[str]:
    """The DEFAULT search space, widened by a trusted calibration profile."""
    out = list(DEFAULT_DATAFLOWS)
    if _trusted(calibration):
        out += list(CALIBRATED_DATAFLOWS)
    return out


# insight-score priority weight per dataflow (multiplied into the negated
# utilization proxy below; smaller weight = enumerated/priced later). Shared
# with the closed-form generator (core/analytic.py) so the two candidate
# sources rank by the same prior.
DATAFLOW_WEIGHT = {"summa": 1.0, "splitk_summa": 0.98, "systolic": 0.9,
                   "systolic_over_summa": 0.92, "summa_over_systolic": 0.9,
                   "baseline": 0.1}


@dataclasses.dataclass
class TunedResult:
    schedule: Schedule
    report: PerfReport
    candidates_tried: int
    # (describe, ranking_cost, utilization) per candidate tried — the cost
    # is the calibrated prediction when a trusted profile ranked the
    # search, NOT always analytical seconds (check `calibration` below).
    log: List[Tuple[str, float, float]]
    # digest of the trusted CalibrationProfile that ranked the candidates
    # ("" = ranked by the raw analytical prior).
    calibration: str = ""


def _pow2_range(lo: int, hi: int) -> List[int]:
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def _engine_friendly(tn: int, hw: AcceleratorConfig) -> float:
    """Fraction of engine columns busy for an N-tile of size tn (alignment)."""
    cc = hw.tile.ce_cols
    return tn / (math.ceil(tn / cc) * cc)


def insight_base(tm: int, tn: int, tk_eff: int,
                 hw: AcceleratorConfig) -> float:
    """Dataflow-independent part of the insight score: predicted engine
    utilization = M/N alignment x K-pipeline ceiling TK/(TK+fill), negated
    so lower = better. Split out so callers scoring one tile geometry
    under several dataflows (core/analytic.py) pay for it once."""
    fill = hw.tile.ce_rows + hw.tile.ce_cols
    eff_m = tm / (math.ceil(tm / hw.tile.ce_rows) * hw.tile.ce_rows)
    ceil_k = tk_eff / (tk_eff + fill)
    return -(_engine_friendly(tn, hw) * eff_m * ceil_k)


def insight_score(tm: int, tn: int, tk_eff: int, df: str,
                  hw: AcceleratorConfig) -> float:
    """Insight-based candidate priority (lower = better): `insight_base`
    weighted by the dataflow prior. The closed-form generator
    (core/analytic.py) ranks its shortlist by the same score, so the two
    candidate sources agree on what 'promising' means."""
    return insight_base(tm, tn, tk_eff, hw) * DATAFLOW_WEIGHT[df]


def enumerate_candidates(shape: GEMMShape, hw: AcceleratorConfig,
                         dataflows: Optional[List[str]] = None,
                         elem_bytes: int = 1,
                         max_candidates: int = 256,
                         calibration=None) -> Iterator[Schedule]:
    """Legal schedule candidates, insight-ordered (most promising first).

    The default dataflow set matches the paper's search space; passing
    `dataflows` explicitly widens or narrows it — including the
    hierarchical compositions (`systolic_over_summa` / `summa_over_systolic`,
    enumerated with the paper's (2, 2) inner group), which restricted
    searches (e.g. `dryrun --route-dataflows`) use to force Fig. 6c/6d
    schedules into the plan cache. A trusted (fit_ok) `calibration` profile
    widens the DEFAULT set with both hierarchical compositions — measured
    validation is the admission ticket.
    """
    rows, cols = hw.grid
    n_tiles = rows * cols
    dataflows = dataflows or default_dataflows(calibration)

    cands: List[Tuple[float, Schedule]] = []
    # the tk >= k_local clamp makes distinct tk values collapse onto the same
    # effective tiling; dedupe so max_candidates isn't spent on repeats.
    seen: set = set()
    # logical grids: gm * gn * gk == n_tiles, all powers of two.
    for gk in _pow2_range(1, n_tiles):
        rest = n_tiles // gk
        if rest * gk != n_tiles:
            continue
        for gm in _pow2_range(1, rest):
            gn = rest // gm
            if gm * gn != rest:
                continue
            # macro-iteration factors keep per-tile tiles engine-sized
            for iter_m in (1, 2, 4):
                for iter_n in (1, 2, 4):
                    if shape.m % (gm * iter_m) or shape.n % (gn * iter_n) or shape.k % gk:
                        continue
                    tm = shape.m // (gm * iter_m)
                    tn = shape.n // (gn * iter_n)
                    k_local = shape.k // gk
                    if tm == 0 or tn == 0 or k_local == 0:
                        continue
                    for tk in (64, 128, 256, 512):
                        if k_local % tk and k_local > tk:
                            continue
                        tk_eff = min(tk, k_local)
                        # L1 feasibility pre-check: double-buffered A/B + fp32 C
                        l1 = (2 * (tm * tk_eff + tk_eff * tn) * elem_bytes
                              + tm * tn * 4)
                        acc_bytes = 4
                        if l1 > hw.tile.l1_bytes:
                            # retry with fp16 accumulation (Insight-3 flat cases)
                            l1 = (2 * (tm * tk_eff + tk_eff * tn) * elem_bytes
                                  + tm * tn * 2)
                            acc_bytes = 2
                            if l1 > hw.tile.l1_bytes:
                                continue
                        for df in dataflows:
                            if df != "splitk_summa" and gk != 1:
                                continue
                            if df == "splitk_summa" and gk < 2:
                                continue
                            if df == "systolic" and (gm == 1 or gn == 1):
                                continue
                            if df in ("systolic_over_summa",
                                      "summa_over_systolic") \
                                    and (gm % 2 or gn % 2
                                         or (shape.k // tk_eff) % 2):
                                # hierarchical candidates use the paper's
                                # square (2, 2) inner group, which must
                                # divide the logical grid AND the K-step
                                # count (every such candidate previously
                                # died at build time during pricing)
                                continue
                            # insight-based priority scoring (lower =
                            # better) — iteration 8 of §Perf: the K-pipeline
                            # ceiling term is what surfaces deep-TK
                            # schedules that tile-size-only scoring missed.
                            score = insight_score(tm, tn, tk_eff, df, hw)
                            key = (gm, gn, gk, iter_m, iter_n, tk_eff, df,
                                   acc_bytes)
                            if key in seen:
                                continue
                            seen.add(key)
                            cands.append((score, Schedule(
                                shape=shape,
                                tiling=Tiling(gm, gn, gk, iter_m, iter_n, tk_eff),
                                dataflow=df, inner=(2, 2),
                                elem_bytes=elem_bytes,
                                acc_bytes=acc_bytes,
                                elem_dtype=default_elem_dtype(elem_bytes, hw))))
    cands.sort(key=lambda sc: sc[0])
    for _, sched in cands[:max_candidates]:
        yield sched


def price_candidates(candidates: Iterator[Schedule], hw: AcceleratorConfig,
                     store_stage_options: Tuple[int, ...] = (1, 4),
                     calibration=None,
                     inner_kernels="auto"
                     ) -> Tuple[Optional[Tuple[float, Schedule, PerfReport]],
                                List[Tuple[str, float, float]], int]:
    """The shared pricing loop behind `tune` and `analytic.analytic_tune`:
    build each candidate into a BSP program (sweeping store stages) and
    price it with the SoftHier model, ranked by the calibration-aware cost.
    Returns (best, log, tried) where best is (cost, schedule, report) — or
    None when no candidate built legally.

    `inner_kernels` makes the intra-device level part of the same search:

    - `"auto"` (default): each outer candidate is joint-priced against its
      closed-form `inner_kernel_candidates` shortlist PLUS the bare
      `None` (XLA-picks) path. A schedule arriving with an explicit
      `inner_kernel` is priced only under it (the caller already chose).
    - `None`: legacy single-level pricing — every candidate keeps
      `inner_kernel=None`.
    - a tuple of `InnerKernel`s (or `None`s): the explicit sweep set.

    Inner candidates are swept BEFORE `None` and the best is kept by strict
    `<`, so when a planner-visible kernel prices exactly like the opaque
    path (the aligned-geometry tie the cost model constructs on purpose)
    the plan carries real, reportable geometry. Communication pricing runs
    once per program (`estimate_sweep`), so the joint search costs one comm
    pass plus a cheap compute recombination per inner candidate.
    """
    cost = ranking_cost(calibration)
    best: Optional[Tuple[float, Schedule, PerfReport]] = None
    log: List[Tuple[str, float, float]] = []
    tried = 0
    for base in candidates:
        for stages in store_stage_options:
            sched = dataclasses.replace(base, store_stages=stages)
            try:
                prog = build_program(sched, hw)
            except (ValueError, KeyError):
                continue
            if sched.inner_kernel is not None:
                inners = (sched.inner_kernel,)
            elif inner_kernels == "auto":
                inners = inner_kernel_candidates(sched, hw) + (None,)
            elif inner_kernels is None:
                inners = (None,)
            else:
                inners = tuple(inner_kernels)
            for ik, rep in estimate_sweep(prog, hw, inners):
                cand = (sched if ik is sched.inner_kernel
                        else dataclasses.replace(sched, inner_kernel=ik))
                tried += 1
                log.append((cand.describe(), cost(rep), rep.utilization(hw)))
                if best is None or cost(rep) < best[0]:
                    best = (cost(rep), cand, rep)
    return best, log, tried


def tune(shape: GEMMShape, hw: AcceleratorConfig,
         dataflows: Optional[List[str]] = None,
         elem_bytes: int = 1,
         max_candidates: int = 48,
         store_stage_options: Tuple[int, ...] = (1, 4),
         calibration=None) -> TunedResult:
    """Build + price candidates; return the fastest schedule.

    With a trusted `calibration` profile, candidates are ranked by the
    calibrated cost (`profile.predict` over the analytical report) — the
    measured per-resource scale factors decide the winner, not the raw
    prior. The winning plan's report stays analytical (the fleet-wide
    comparable number); the ranking provenance is in
    `TunedResult.calibration`.
    """
    best, log, tried = price_candidates(
        enumerate_candidates(shape, hw, dataflows, elem_bytes,
                             max_candidates=max_candidates,
                             calibration=calibration),
        hw, store_stage_options, calibration)
    if best is None:
        raise RuntimeError(f"no legal schedule found for {shape} on {hw.name}")
    return TunedResult(schedule=best[1], report=best[2],
                       candidates_tried=tried, log=log,
                       calibration=calibration.digest()
                       if _trusted(calibration) else "")


def tune_cached(shape: GEMMShape, hw: AcceleratorConfig,
                cache, **tune_kwargs) -> TunedResult:
    """Cache-aware `tune`: consult a `repro.deploy.PlanCache` first.

    A hit returns immediately with candidates_tried == 0 (no enumeration, no
    pricing); a miss runs the normal search and persists the winner. This is
    the minimal entry point for callers that don't want a full
    `repro.deploy.Planner` (which adds shape bucketing and refinement).

    A `dataflows` restriction keys its plans under a separate cache variant,
    so constrained searches never collide with (or clobber) the unrestricted
    winners. Other knobs (max_candidates, store_stage_options) affect search
    effort, not validity, so a hit tuned under different effort is served —
    but a hit ranked under a different calibration regime (see
    `repro.deploy.Planner._admissible`) is NOT: it gets re-tuned and
    replaced, so a trusted profile never becomes a silent no-op against a
    previously warmed cache.
    """
    from repro.deploy.plan import (plan_admissible,   # deploy imports us
                                   plan_from_tuning, search_variant)

    elem_bytes = tune_kwargs.get("elem_bytes", 1)
    # [] means 'unrestricted' to enumerate_candidates; keep the cache
    # variant and the admissibility check consistent with that.
    dataflows = tune_kwargs.get("dataflows") or None
    calibration = tune_kwargs.get("calibration")
    regime = calibration.digest() if _trusted(calibration) else ""
    variant = search_variant(dataflows)
    plan = cache.get(shape, elem_bytes, hw, variant)
    if plan is not None and not plan_admissible(plan, dataflows, regime):
        plan = None      # wrong dataflow space or calibration regime
    if plan is not None:
        return TunedResult(schedule=plan.schedule, report=plan.report,
                           candidates_tried=0, log=[],
                           calibration=plan.calibration_digest)
    res = tune(shape, hw, **tune_kwargs)
    cache.put(plan_from_tuning(shape, hw, res.schedule, res.report,
                               candidates_tried=res.candidates_tried,
                               variant=variant,
                               calibration_digest=res.calibration))
    return res
