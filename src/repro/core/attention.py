"""Fused attention dataflow (FlatAttention): QKᵀ → online softmax → PV as
one tiled superstep sequence with per-composition collectives.

The unfused path runs attention as three independently-routed projection
GEMMs plus a stock softmax — the score matrix round-trips through memory
and the planner never sees the composition. FlatAttention (PAPERS.md) shows
MHA on tile-based many-PE accelerators wants its *own* dataflow: stream KV
tiles through L1, keep the online-softmax running stats (m, l) and the
output accumulator resident, and choose the collective per composition:

- **merge** — KV is sharded over the mesh's row axis; every device scans
  its local KV shard with the online-softmax recurrence, then one combine
  superstep reduces the partials across the row:
  ``m_g = pmax(m)``, ``l_g = psum(exp(m - m_g) * l)``,
  ``o_g = psum(exp(m - m_g) * acc)``, ``out = o_g / l_g``.
- **ring** — Q is additionally sharded over the row axis (sq blocks); the
  KV shards rotate around a `ppermute` ring so each device sees the full
  KV stream in dm supersteps, carrying (m, l, acc) through the scan.

Head sharding over the column axis is a lowering legality question
(`lower_attention`), not a tunable: query heads must divide the axis and
KV heads must divide too or be fully replicable (MQA / MLA-absorbed).

Layering mirrors `core/lower.py`: the planning half (`attn_candidates`,
`attn_tune`) is importable without jax — the deploy layer prices attention
schedules with `sim.perf.estimate_attention` under the same calibrated
`CalibrationProfile` as GEMMs. Only `flat_attention` (the shard_map
executor `models.matmul.pattn` dispatches to) imports jax, lazily.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.autotuner import TunedResult
from repro.core.schedule import (ATTN_COMPOSITIONS, AttnSchedule, AttnShape,
                                 default_elem_dtype)
from repro.hw.config import AcceleratorConfig
from repro.sim.calibrate import is_trusted, ranking_cost
from repro.sim.perf import estimate_attention

NEG_INF = -1e30

# KV-chunk menu, largest first (larger chunks amortize softmax passes and
# barriers; smaller ones fit the L1 working set) — same shape as the
# analytic shortlist's _TK_MENU.
_KV_CHUNK_MENU = (512, 256, 128, 64)


def _head_shard(shape: AttnShape, dn: int) -> bool:
    return (dn > 1 and shape.h % dn == 0
            and (shape.hkv % dn == 0 or shape.hkv == 1))


def attn_candidates(shape: AttnShape, hw: AcceleratorConfig,
                    elem_bytes: int = 4) -> Tuple[AttnSchedule, ...]:
    """Closed-form fused-attention candidates for `shape` on `hw`.

    The space is composition × kv_chunk — tiny, so the planner prices it
    inline (no bucketing, no background refinement). Only legal candidates
    are emitted: skv must shard over the row axis, ring additionally needs
    sq to (decode's sq=1 gets merge only), and the per-(batch, head) L1
    working set must fit the tile.
    """
    dm, dn = hw.grid
    if dm > 0 and shape.skv % dm:
        return ()
    kv_l = max(1, shape.skv // max(1, dm))
    head_shard = _head_shard(shape, dn)
    dtype = default_elem_dtype(elem_bytes, hw)
    comps = ["merge"]
    if dm > 1 and shape.sq % dm == 0:
        comps.append("ring")
    out, seen = [], set()
    for comp in comps:
        sq_l = shape.sq // dm if comp == "ring" else shape.sq
        for target in _KV_CHUNK_MENU:
            chunk = min(target, kv_l)
            if (comp, chunk) in seen:
                continue
            # working set per (batch, head): resident Q block + streamed
            # KV chunk + fp32 logits + fp32 (m, l, acc)
            ws = ((sq_l * shape.d + chunk * (shape.d + shape.dv)) * elem_bytes
                  + sq_l * chunk * 4 + sq_l * (2 + shape.dv) * 4)
            if ws > hw.tile.l1_bytes:
                continue
            seen.add((comp, chunk))
            out.append(AttnSchedule(shape=shape, composition=comp,
                                    kv_chunk=chunk, elem_bytes=elem_bytes,
                                    elem_dtype=dtype))
    return tuple(out)


def attn_tune(shape: AttnShape, hw: AcceleratorConfig, elem_bytes: int = 4,
              calibration=None) -> TunedResult:
    """Pick the best fused-attention schedule for `shape` on `hw`.

    Prices every candidate with `estimate_attention` and ranks by the same
    `ranking_cost` as the GEMM tuners: the calibrated prediction under a
    trusted `CalibrationProfile`, else the analytical prior. Raises
    `RuntimeError` when no fused candidate is legal (the planner treats
    that as an unresolvable shape, exactly like `analytic_tune`).
    """
    cands = attn_candidates(shape, hw, elem_bytes=elem_bytes)
    if not cands:
        raise RuntimeError(f"no legal flat-attention candidate for "
                           f"{shape.describe()} on {hw.name}")
    cost_fn = ranking_cost(calibration)
    best = None
    log = []
    for cand in cands:
        # cost_fn applies the trusted profile itself (profile.predict over
        # the analytical report) — same contract as price_candidates: the
        # stored report stays analytical, ranking provenance in `calibration`
        report = estimate_attention(cand, hw)
        cost = cost_fn(report)
        log.append((cand.describe(), cost, report.utilization(hw)))
        if best is None or cost < best[0]:
            best = (cost, cand, report)
    _, sched, report = best
    return TunedResult(schedule=sched, report=report,
                       candidates_tried=len(cands), log=log,
                       calibration=(calibration.digest()
                                    if is_trusted(calibration) else ""))


# -- execution ----------------------------------------------------------------

def flat_attention(q, k, v, mesh, exec_plan, *, causal: bool = True,
                   scale: Optional[float] = None, q_positions=None,
                   kv_len=None):
    """Execute fused attention on `mesh` under a lowered `ExecPlan`.

    q: (b, sq, h, d); k: (b, skv, hkv, d); v: (b, skv, hkv, dv) →
    (b, sq, h, dv). GQA grouping (h a multiple of hkv) is handled by
    reshaping q to (…, hkv, g, d); `q_positions` (sq,) and `kv_len` (b,)
    carry decode's absolute positions and valid-cache lengths, with the
    same mask semantics as `models.attention._sdpa`.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    row = exec_plan.axes["row"]
    col = exec_plan.axes["col"]
    comp = exec_plan.kwargs.get("composition", "merge")
    head_shard = bool(exec_plan.kwargs.get("head_shard", False))
    dm = int(mesh.shape[row])
    dn = int(mesh.shape[col])

    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    if scale is None:
        scale = d ** -0.5
    h_l = h // dn if head_shard else h
    hkv_shard = head_shard and hkv % dn == 0 and hkv > 1
    hkv_l = hkv // dn if hkv_shard else hkv
    g = h_l // hkv_l
    kv_l = skv // dm
    ring = comp == "ring" and dm > 1
    sq_l = sq // dm if ring else sq

    qpos = jnp.asarray(q_positions if q_positions is not None
                       else jnp.arange(sq), jnp.int32)
    klen = jnp.asarray(kv_len if kv_len is not None
                       else jnp.full((b,), skv), jnp.int32)

    hq_spec = col if head_shard else None
    hkv_spec = col if hkv_shard else None

    def _masked(logits, kpos, qp, kl):
        # logits: (b, hkv_l, g, sq_l, ck) with kpos (ck,) global positions
        if causal:
            mask = kpos[None, :] <= qp[:, None]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        valid = kpos[None, :] < kl[:, None]
        return jnp.where(valid[:, None, None, None], logits, NEG_INF)

    def _scores(qg, k_c):
        return jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                          k_c.astype(jnp.float32),
                          preferred_element_type=jnp.float32) * scale

    if not ring:
        def body(q_l, k_l, v_l, qp, kl):
            ri = jax.lax.axis_index(row)
            kpos = ri * kv_l + jnp.arange(kv_l)
            qg = q_l.reshape(b, sq, hkv_l, g, d).astype(jnp.float32)
            logits = _masked(_scores(qg, k_l), kpos, qp, kl)
            m_loc = logits.max(axis=-1)
            m_g = jax.lax.pmax(jax.lax.stop_gradient(m_loc), row)
            p = jnp.exp(logits - m_g[..., None])
            l_g = jax.lax.psum(p.sum(axis=-1), row)
            # normalize BEFORE PV (l_g is already global, so this is legal
            # at any dm) and multiply at the value dtype — the same
            # normalize-then-cast rounding as _sdpa's softmax, so routed
            # and unfused numerics agree to dtype precision
            probs = p / jnp.maximum(l_g, 1e-30)[..., None]
            out = jax.lax.psum(
                jnp.einsum("bhgqk,bkhd->bhgqd", probs.astype(v_l.dtype),
                           v_l, preferred_element_type=jnp.float32), row)
            return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h_l, dv)

        out = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, None, hq_spec, None),
                      P(None, row, hkv_spec, None),
                      P(None, row, hkv_spec, None), P(), P()),
            out_specs=P(None, None, hq_spec, None),
            check_rep=False)(q, k, v, qpos, klen)
        return out.astype(q.dtype)

    perm = [(j, (j + 1) % dm) for j in range(dm)]

    def ring_body(q_l, k_l, v_l, qp_l, kl):
        ri = jax.lax.axis_index(row)
        qg = q_l.reshape(b, sq_l, hkv_l, g, d).astype(jnp.float32)

        def step(carry, t):
            m_run, l_run, acc, k_c, v_c = carry
            # at step t this device holds the shard ring-shifted from
            # source (ri - t) mod dm, whose global KV offset anchors masks
            src = (ri - t) % dm
            kpos = src * kv_l + jnp.arange(kv_l)
            logits = _masked(_scores(qg, k_c), kpos, qp_l, kl)
            m_new = jnp.maximum(
                m_run, jax.lax.stop_gradient(logits.max(axis=-1)))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_c.dtype), v_c,
                preferred_element_type=jnp.float32)
            k_c = jax.lax.ppermute(k_c, row, perm)
            v_c = jax.lax.ppermute(v_c, row, perm)
            return (m_new, l_new, acc_new, k_c, v_c), None

        m0 = jnp.full((b, hkv_l, g, sq_l), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv_l, g, sq_l), jnp.float32)
        a0 = jnp.zeros((b, hkv_l, g, sq_l, dv), jnp.float32)
        # K/V ride the ring at the operand dtype (the scores einsum
        # upcasts K per step; PV matches _sdpa's probs-cast rounding)
        carry = (m0, l0, a0, k_l, v_l)
        (m, l, acc, _, _), _ = jax.lax.scan(step, carry, jnp.arange(dm))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq_l, h_l, dv)

    out = shard_map(
        ring_body, mesh=mesh,
        in_specs=(P(None, row, hq_spec, None),
                  P(None, row, hkv_spec, None),
                  P(None, row, hkv_spec, None), P(row), P()),
        out_specs=P(None, row, hq_spec, None),
        check_rep=False)(q, k, v, qpos, klen)
    return out.astype(q.dtype)
