"""Sharding rule table: parameter/batch/cache PartitionSpecs for the
production mesh — the data-layout half of the DiT schedule at pod scale.

The split-scheme analogy (DESIGN.md §2.2): choosing which mesh axes a tensor's
dims map to IS the paper's §3.2.1 split scheme (which chip's HBM owns which
block); XLA's within-shard layout is the placement scheme.

Policy: 2-D FSDP x TP. Weight matrices shard their input dim over 'data'
(FSDP — gathered on use) and output dim over 'model' (TP). MoE experts shard
the expert dim over 'model' (EP) and d_model over 'data'. Every rule is
fitted: an axis that does not divide the dim is dropped (robustness across
all 10 archs and both meshes). The 'pod' axis is pure DP (it never appears in
weight specs; gradients cross pods in one hierarchical all-reduce).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fit(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec axes that don't divide the corresponding dim."""
    out = []
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


# rules keyed by the LAST path component (parameter name); specs refer to the
# trailing dims of the leaf (leading stacked-layer dims get None).
_W_IN = P("data", "model")     # (d_in, d_out) column-parallel
_W_OUT = P("model", "data")    # (d_in, d_out) row-parallel

PARAM_RULES: Dict[str, P] = {
    "embed": P("model", "data"),          # vocab-parallel embedding
    "lm_head": P("data", "model"),
    "frontend_proj": _W_IN,
    # attention
    "wq": _W_IN, "wk": _W_IN, "wv": _W_IN, "wo": _W_OUT,
    "w_dq": _W_IN, "w_uq": _W_IN, "w_dkv": _W_IN, "w_kr": _W_IN,
    "w_uk": _W_IN, "w_uv": _W_IN,
    # mlp
    "gate": _W_IN, "up": _W_IN, "down": _W_OUT,
    # moe
    "router": P("data", None),
    # ssm / xlstm
    "w_in": _W_IN, "w_out": _W_OUT, "conv": P(None, "model"),
    "a_log": P(None), "d_skip": P(None), "dt_bias": P(None),
    "w_up": _W_IN, "w_q": _W_IN, "w_k": _W_IN, "w_v": _W_IN,
    "w_gates": P("data", None), "w_down": _W_OUT,
    "r": P(None),
    # norms
    "scale": P(None),
}

# MoE expert tensors are 3-D (E, d_in, d_out): EP over 'model' + FSDP 'data'.
MOE_EXPERT_RULES: Dict[str, P] = {
    "gate": P("model", "data", None),
    "up": P("model", "data", None),
    "down": P("model", None, "data"),
}


def param_spec(path: Tuple[Any, ...], leaf: jax.ShapeDtypeStruct,
               mesh: Mesh) -> P:
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    name = keys[-1]
    in_experts = "experts" in keys
    rule = (MOE_EXPERT_RULES if in_experts else PARAM_RULES).get(name)
    if rule is None:
        rule = P()
    # pad for stacked-layer leading dims
    extra = len(leaf.shape) - len(rule)
    if extra > 0:
        rule = P(*([None] * extra + list(rule)))
    return _fit(rule, leaf.shape, mesh)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    """Pytree of NamedShardings matching a params (or opt-state) shape tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        params_shape)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh) -> P:
    """Token batches: batch over all DP axes."""
    return P(dp_axes(mesh), None)


def cache_spec(path: Tuple[Any, ...], leaf: jax.ShapeDtypeStruct,
               mesh: Mesh, cfg: ModelConfig, batch: int) -> P:
    """Decode caches: batch over DP when it divides; otherwise shard the
    sequence (long_500k batch=1) or the head/state dims over 'model'."""
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    name = keys[-1]
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_ok = batch % dp_size == 0

    def bdim(*rest):
        return P(dp if batch_ok else None, *rest)

    if name == "index":
        return P(*([None] * len(leaf.shape)))
    # leading dim is stacked layers, second is batch
    if name in ("k", "v"):          # (L, B, S, n_kv, hd)
        # prefer kv-head sharding over 'model'; GQA archs with n_kv < |model|
        # (qwen3/phi4 kv=8, gemma kv=1) fall back to SEQUENCE sharding — the
        # cache is by far the largest decode tensor (32k x batch) and leaving
        # it replicated over 'model' costs ~16x HBM (observed 172 GB/dev).
        n_kv = leaf.shape[3]
        if n_kv % mesh.shape["model"] == 0:
            spec = P(None, dp if batch_ok else None, None, "model", None)
        else:
            spec = P(None, dp if batch_ok else None, "model", None, None)
        return _fit(spec, leaf.shape, mesh)
    if name == "c_kv":              # (L, B, S, r)
        return _fit(P(None, dp if batch_ok else None,
                      None if batch_ok else "data", "model"), leaf.shape, mesh)
    if name == "k_rope":            # (L, B, S, 1, dr)
        return _fit(P(None, dp if batch_ok else None,
                      None if batch_ok else "data", None, None),
                    leaf.shape, mesh)
    if name == "h":                 # mamba (L, B, H, N, P) / slstm (L,B,H,hd)
        return _fit(P(None, dp if batch_ok else None, "model"), leaf.shape, mesh)
    if name in ("c", "n", "m"):     # xlstm states (L, B, H, ...)
        return _fit(P(None, dp if batch_ok else None, "model"), leaf.shape, mesh)
    if name == "conv":              # (L, B, 3, C)
        return _fit(P(None, dp if batch_ok else None, None, "model"),
                    leaf.shape, mesh)
    return P(*([None] * len(leaf.shape)))


def cache_shardings(cache_shape: Any, mesh: Mesh, cfg: ModelConfig,
                    batch: int) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf, mesh, cfg, batch)),
        cache_shape)
