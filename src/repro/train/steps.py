"""Training and serving step functions — the graphs the dry-run lowers.

`make_train_step` builds a donated, optionally-microbatched step:
loss -> grads (optionally int8-compressed with error feedback before the
cross-pod reduction) -> AdamW update. Remat is on by default (scan-level
jax.checkpoint). `make_serve_step` wraps decode_step for batched requests.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.model import decode_step, forward, lm_head_weight
from repro.optim import adamw, compress

LOSS_CHUNK = 512


def _constrain_logits(x, vocab):
    from repro.models import shard_ctx
    mesh = shard_ctx.get_mesh()
    if mesh is None or vocab % mesh.shape["model"]:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, None, "model")))


def chunked_xent(hidden: jax.Array, head_w: jax.Array, targets: jax.Array,
                 vocab: int, chunk: int = LOSS_CHUNK) -> jax.Array:
    """Fused softmax-CE: project vocab logits chunk-by-chunk along the
    sequence (remat'd scan) so the fp32 (B, S, V) tensor never exists —
    unsharded-vocab archs were paying up to 270 GB/device for it."""
    from repro.models import accounting
    b, s, d = hidden.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    n = s // c
    hs = hidden.reshape(b, n, c, d).swapaxes(0, 1)          # (n, b, c, d)
    ts = targets.reshape(b, n, c).swapaxes(0, 1)

    def body(acc, xs):
        h_c, t_c = xs
        from repro.models.matmul import pmm
        logits = _constrain_logits(
            pmm(h_c, head_w, tag="lm_head.chunked").astype(jnp.float32),
            vocab)                                          # (b, c, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return acc + (logz - gold).sum(), None

    total, _ = accounting.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                               (hs, ts))
    return total / (b * s)


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            remat: bool = True) -> jax.Array:
    kwargs = {}
    if cfg.frontend == "vision_stub":
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    if cfg.is_encoder_decoder:
        kwargs["encoder_embeds"] = batch["encoder_embeds"]
    hidden = forward(params, batch["tokens"], cfg, remat=remat,
                     return_hidden=True, **kwargs)
    if cfg.frontend == "vision_stub":
        hidden = hidden[:, -batch["tokens"].shape[1]:]   # drop prefix positions
    return chunked_xent(hidden, lm_head_weight(params, cfg),
                        batch["targets"], cfg.vocab)


def make_train_step(cfg: ModelConfig, opt: adamw.AdamWConfig,
                    microbatches: int = 1,
                    compress_grads: bool = False,
                    remat: bool = True) -> Callable:
    """Returns train_step(params, opt_state, comp_state, batch) ->
    (params, opt_state, comp_state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, remat=remat))(params)

    def train_step(params, opt_state, comp_state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (0.0, zero), micro)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = grads_of(params, batch)

        if compress_grads:
            grads, comp_state = compress.apply(grads, comp_state)

        params, opt_state, metrics = adamw.apply(opt, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, comp_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, caches, tokens, position[, encoder_out]) ->
    (next_token_logits, caches) — ONE new token against the running cache
    (the brief's decode_* shapes lower this, not train_step)."""

    def serve_step(params, caches, tokens, position, encoder_out=None):
        return decode_step(params, caches, tokens, position, cfg,
                           encoder_out=encoder_out)

    return serve_step


def make_prefill(cfg: ModelConfig) -> Callable:
    """prefill(params, tokens[, extras]) -> logits — the prefill_32k graph."""

    def prefill(params, tokens, prefix_embeds=None, encoder_embeds=None):
        kwargs = {}
        if prefix_embeds is not None:
            kwargs["prefix_embeds"] = prefix_embeds
        if encoder_embeds is not None:
            kwargs["encoder_embeds"] = encoder_embeds
        return forward(params, tokens, cfg, remat=False, **kwargs)

    return prefill
