"""Jit'd public wrappers for the Pallas kernels.

`tile_matmul` is the deployment entry point the model stack uses on TPU: it
pads to MXU-aligned block multiples (the placement-scheme alignment of §3.2.2
— irregular tiles are exactly what the paper's Insight 3 warns about), picks a
block shape that fits VMEM, and dispatches to the `mmad` kernel. On CPU (this
container) it routes through the pure-jnp oracle unless `interpret=True`
Pallas execution is requested explicitly — numerics are identical.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.mmad import mmad

# VMEM working-set budget for picking block shapes (bytes); a v5e has ~128 MB
# but Pallas double-buffers every operand block, so stay well under.
_VMEM_BUDGET = 8 * 1024 * 1024


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


def pick_block_shape(m: int, n: int, k: int, elem_bytes: int = 2
                     ) -> Tuple[int, int, int]:
    """MXU-aligned block shape whose double-buffered working set fits VMEM.

    This is the intra-chip analogue of the schedule abstraction's tiling
    choice: prefer (128, 128, bk) with the largest bk that fits (larger K
    chunks amortize the accumulator flush, the same effect as the paper's
    larger TK on the matrix engine)."""
    bm = min(128, _round_up(m, 8))
    bn = min(128, _round_up(n, 128))
    bk = 128
    while True:
        nxt = bk * 2
        ws = (bm * nxt + nxt * bn) * elem_bytes * 2 + bm * bn * 4
        if nxt <= k and ws <= _VMEM_BUDGET:
            bk = nxt
        else:
            break
    return bm, bn, min(bk, _round_up(k, 128))


@functools.partial(jax.jit, static_argnames=("block_shape", "interpret", "use_kernel"))
def tile_matmul(a: jax.Array, b: jax.Array,
                block_shape: Optional[Tuple[int, int, int]] = None,
                interpret: bool = False,
                use_kernel: Optional[bool] = None) -> jax.Array:
    """C = A @ B via the Pallas MMAD kernel with padding to block multiples."""
    m, k = a.shape
    _, n = b.shape
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu or interpret
    if not use_kernel:
        return ref.mmad_ref(a, b)

    bs = block_shape or pick_block_shape(m, n, k, a.dtype.itemsize)
    bm, bn, bk = bs
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    ap = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp != m or kp != k) else a
    bp = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp != k or np_ != n) else b
    out = mmad(ap, bp, block_shape=(bm, bn, bk), interpret=not on_tpu)
    return out[:m, :n]
