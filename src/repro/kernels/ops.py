"""Jit'd public wrappers for the Pallas kernels.

`tile_matmul` is the deployment entry point the model stack uses on TPU: it
pads to MXU-aligned block multiples (the placement-scheme alignment of §3.2.2
— irregular tiles are exactly what the paper's Insight 3 warns about), picks a
block shape that fits VMEM, and dispatches to the `mmad` kernel. On CPU (this
container) it routes through the pure-jnp oracle unless `interpret=True`
Pallas execution is requested explicitly — numerics are identical.

`local_matmul` is the schedule-resolved entry point: the mesh dataflows in
`core/gemm.py` call it with the lowered plan's `InnerKernel`, so the planner's
block geometry / pipeline depth / compute dtype choice actually reaches the
per-device GEMM. It is reverse-differentiable (`jax.custom_vjp`) so routed
training keeps working, and it never *narrows* operands: casting to the
kernel's dtype happens only when that dtype is at least as wide as the data —
quantizing to fp8/int8 is the model's decision, not the scheduler's.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ir import ELEM_BYTES_OF_DTYPE
from repro.core.schedule import INNER_VMEM_BUDGET, InnerKernel
from repro.kernels import ref
from repro.kernels.mmad import mmad

# VMEM working-set budget for picking block shapes (bytes); a v5e has ~128 MB
# but Pallas double-buffers every operand block, so stay well under. Shared
# with the schedule level: `InnerKernel.validate` and the lowering demotion
# enforce the same ceiling, so a plan-carried kernel always dispatches.
_VMEM_BUDGET = INNER_VMEM_BUDGET

# schedule dtype names -> jnp dtypes for the compute-dtype cast. fp8 uses the
# e4m3 variant jax ships (OCP float8_e4m3fn); accumulation is fp32 regardless.
_JNP_OF_DTYPE = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "float8_e4m3": jnp.float8_e4m3fn,
    "int8": jnp.int8,
}


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


def pick_block_shape(m: int, n: int, k: int, elem_bytes: int = 2
                     ) -> Tuple[int, int, int]:
    """MXU-aligned block shape whose double-buffered working set fits VMEM.

    This is the intra-chip analogue of the schedule abstraction's tiling
    choice: prefer (128, 128, bk) with the largest bk that fits (larger K
    chunks amortize the accumulator flush, the same effect as the paper's
    larger TK on the matrix engine).

    The returned `bk` always divides the 128-padded K (`_round_up(k, 128)`),
    so `tile_matmul`'s padding stays at the explicit 128-alignment — no
    silent reliance on bk-sized padding for ragged K."""
    bm = min(128, _round_up(m, 8))
    bn = min(128, _round_up(n, 128))
    kp = _round_up(k, 128)
    bk = 128
    while True:
        nxt = bk * 2
        ws = (bm * nxt + nxt * bn) * elem_bytes * 2 + bm * bn * 4
        if nxt <= kp and kp % nxt == 0 and ws <= _VMEM_BUDGET:
            bk = nxt
        else:
            break
    return bm, bn, min(bk, kp)


@functools.partial(jax.jit,
                   static_argnames=("block_shape", "interpret", "use_kernel",
                                    "out_dtype"))
def tile_matmul(a: jax.Array, b: jax.Array,
                block_shape: Optional[Tuple[int, int, int]] = None,
                interpret: bool = False,
                use_kernel: Optional[bool] = None,
                out_dtype=None) -> jax.Array:
    """C = A @ B via the Pallas MMAD kernel with padding to block multiples."""
    m, k = a.shape
    _, n = b.shape
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu or interpret
    if not use_kernel:
        out = ref.mmad_ref(a, b)
        return out.astype(out_dtype) if out_dtype is not None else out

    bs = block_shape or pick_block_shape(m, n, k, a.dtype.itemsize)
    bm, bn, bk = bs
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    ap = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp != m or kp != k) else a
    bp = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp != k or np_ != n) else b
    out = mmad(ap, bp, block_shape=(bm, bn, bk), interpret=not on_tpu,
               out_dtype=out_dtype)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Schedule-resolved local matmul (the two-level tuning dispatch point)
# ---------------------------------------------------------------------------

def _cast_operand(x: jax.Array, kernel: InnerKernel) -> jax.Array:
    """Cast to the kernel's compute dtype UNLESS that would narrow the data.

    The planner may pick an fp8 kernel for an fp8-native part; if the model
    actually feeds fp32 activations, quantization is its call to make — the
    dispatch must not silently destroy precision. Widening (bf16 data on an
    fp32 kernel) is always safe."""
    if not kernel.dtype:
        return x
    want = _JNP_OF_DTYPE.get(kernel.dtype)
    if want is None:
        return x
    have_bytes = x.dtype.itemsize
    want_bytes = ELEM_BYTES_OF_DTYPE[kernel.dtype]
    if want_bytes < have_bytes:
        return x
    # never cross float/int kinds either (int8-kernel on fp8 data would
    # reinterpret values, not widen them)
    if (jnp.issubdtype(x.dtype, jnp.floating)
            != jnp.issubdtype(want, jnp.floating)):
        return x
    return x.astype(want)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def local_matmul(a: jax.Array, b: jax.Array, kernel: InnerKernel,
                 interpret: bool = False) -> jax.Array:
    """Per-device C = A @ B under a planner-resolved inner kernel, fp32 out.

    On TPU (or under `interpret=True`) this is the Pallas `mmad` kernel at
    the kernel's block geometry; on CPU it is the bitwise jnp oracle — the
    exact expression the mesh dataflows used before routing was kernel-aware,
    so enabling inner kernels does not move routed numerics on this host.
    Reverse-differentiable via `jax.custom_vjp` (transposed fp32 matmuls), so
    routed training works through the Pallas path too.
    """
    return _local_matmul_impl(a, b, kernel, interpret)


def _local_matmul_impl(a, b, kernel, interpret):
    a = _cast_operand(a, kernel)
    b = _cast_operand(b, kernel)
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or interpret):
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    return tile_matmul(a, b, block_shape=kernel.geometry(),
                       interpret=interpret, use_kernel=True,
                       out_dtype=jnp.float32)


def _local_matmul_fwd(a, b, kernel, interpret):
    return _local_matmul_impl(a, b, kernel, interpret), (a, b)


def _local_matmul_bwd(kernel, interpret, res, g):
    a, b = res
    g32 = g.astype(jnp.float32)
    da = jnp.dot(g32, b.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32).astype(a.dtype)
    db = jnp.dot(a.astype(jnp.float32).T, g32,
                 preferred_element_type=jnp.float32).astype(b.dtype)
    return da, db


local_matmul.defvjp(_local_matmul_fwd, _local_matmul_bwd)
