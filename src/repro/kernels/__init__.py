from repro.kernels import ops, ref
from repro.kernels.mmad import mmad
from repro.kernels.ops import tile_matmul
