"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mmad_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with float32 accumulation — the MMAD oracle."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def splitk_ref(a: jax.Array, b: jax.Array, splits: int, out_dtype=None) -> jax.Array:
    """Split-K oracle: partial GEMMs over K slices, then a tree-sum — mirrors
    the NoC reduction semantics (fp32 partials)."""
    out_dtype = out_dtype or a.dtype
    k = a.shape[-1]
    assert k % splits == 0
    ks = k // splits
    parts = [jnp.dot(a[..., i * ks:(i + 1) * ks], b[i * ks:(i + 1) * ks, :],
                     preferred_element_type=jnp.float32)
             for i in range(splits)]
    return sum(parts).astype(out_dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, scale: float | None = None) -> jax.Array:
    """Softmax attention oracle (fp32 softmax), [heads, seq, head_dim]."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("...qd,...kd->...qk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs.astype(v.dtype), v)
