"""Pallas TPU kernel for the per-tile MMAD (paper Fig. 3b, adapted to TPU).

On SoftHier a compute tile's matrix engine consumes L1-resident A/B tiles and
accumulates C in L1. The TPU analogue: a Pallas kernel whose BlockSpec tiling
streams (bm x bk) / (bk x bn) blocks HBM->VMEM (the placement-scheme tiles of
§3.2.2), feeds the MXU, and keeps a float32 VMEM accumulator across the K
grid dimension — Pallas's implicit pipelining of the grid is the paper's
§3.3.1 double-buffered DMA/compute overlap.

Block shapes default to MXU-aligned (128, 128, 128); the K loop is the
innermost ("arbitrary") grid dimension so the accumulator scratch carries
across it, while M/N are "parallel" dimensions.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific bits; absent/new-API-shaped on some builds
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                               getattr(pltpu, "TPUCompilerParams", None))
except ImportError:  # pragma: no cover
    pltpu = None
    _VMEM = None
    _COMPILER_PARAMS = None


def _mmad_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush at k == n_k-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("block_shape", "interpret", "out_dtype"))
def mmad(a: jax.Array, b: jax.Array,
         block_shape: Tuple[int, int, int] = (128, 128, 128),
         interpret: bool = False,
         out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """C = A @ B with VMEM-tiled blocks and a float32 accumulator.

    Shapes must divide by the block shape (the ops.py wrapper pads otherwise).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    bm, bn, bk = block_shape
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by blocks {block_shape}")
    out_dtype = out_dtype or a.dtype
    n_k = k // bk

    if _VMEM is not None:
        scratch = [_VMEM((bm, bn), jnp.float32)]
    else:  # pragma: no cover
        scratch = [jax.ShapeDtypeStruct((bm, bn), jnp.float32)]

    kwargs = {}
    if not interpret and _COMPILER_PARAMS is not None:
        kwargs["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    kernel = functools.partial(_mmad_kernel, n_k=n_k, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(a, b)
