"""Gradient compression for the cross-pod (slow-link) all-reduce
(DESIGN.md §5 distributed-opt tricks).

int8 blockwise quantization with error feedback: quantize the gradient before
the pod-axis reduction, carry the quantization residual into the next step.
On the dry-run mesh this reduces cross-pod collective bytes 4x (fp32->int8);
tests verify the error-feedback loop keeps a toy optimization converging.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class CompressState(NamedTuple):
    residual: Any                 # error-feedback carry, same tree as grads


def init(grads_like: Any) -> CompressState:
    return CompressState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape)


def compress_decompress(g: jax.Array, residual: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback round: returns (transmitted grad, new residual)."""
    acc = g.astype(jnp.float32) + residual
    q, scale = _quantize(acc)
    deq = _dequantize(q, scale, g.shape)
    return deq.astype(g.dtype), acc - deq


def apply(grads: Any, state: CompressState) -> Tuple[Any, CompressState]:
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    outs = [compress_decompress(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            CompressState(residual=tdef.unflatten([o[1] for o in outs])))
