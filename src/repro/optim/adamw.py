"""AdamW + schedules + global-norm clipping (pure pytree ops — optimizer
states inherit the parameters' shardings, so the optimizer is fully sharded
on the production mesh for free)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype),
                        tree), norm


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def apply(cfg: AdamWConfig, params: Any, grads: Any,
          state: AdamWState) -> Tuple[Any, AdamWState, dict]:
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    lr = lr_at(cfg, step)
    metrics["lr"] = lr
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m / b1c
        vhat = v / b2c
        newp = (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
