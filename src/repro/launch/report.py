"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from results/dryrun.

  PYTHONPATH=src python -m repro.launch.report --out results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(out: str) -> Dict[str, dict]:
    cells = {}
    for path in sorted(glob.glob(os.path.join(out, "*.json"))):
        # observability sidecars live next to the cells; they are not cells
        if path.endswith((".run_report.json", ".trace.json")):
            continue
        with open(path) as f:
            r = json.load(f)
        key = f"{r.get('arch')}|{r.get('shape')}|{'mp' if r.get('multi_pod') else 'sp'}"
        cells[key] = r
    return cells


def fmt_bytes(n: float) -> str:
    return f"{n/1e9:.2f}"


def dryrun_table(cells: Dict[str, dict]) -> List[str]:
    rows = ["| arch | shape | mesh | compile | peak GB/dev | collectives |",
            "|---|---|---|---|---|---|"]
    for key in sorted(cells):
        r = cells[key]
        arch, shape, m = key.split("|")
        mesh = "2x16x16" if m == "mp" else "16x16"
        if r.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | {mesh} | FAIL | - | "
                        f"{str(r.get('error'))[:60]} |")
            continue
        f = r["full"]
        rows.append(
            f"| {arch} | {shape} | {mesh} | ok ({f['compile_s']:.0f}s) | "
            f"{fmt_bytes(f['peak_bytes_per_device'])} | {f['collectives'][:70]} |")
    return rows


def roofline_table(cells: Dict[str, dict]) -> List[str]:
    """Single-pod roofline rows + an explicit tally of every cell left out.

    A skipped cell used to vanish without a trace, so a failed or
    roofline-less run silently shrank the table; now the reasons are
    counted and appended as a visible note."""
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "roofline frac | MODEL/HLO | bottleneck note |",
            "|---|---|---|---|---|---|---|---|---|"]
    skipped: Dict[str, int] = {}
    for key in sorted(cells):
        r = cells[key]
        arch, shape, m = key.split("|")
        if m != "sp":
            skipped["multi-pod"] = skipped.get("multi-pod", 0) + 1
            continue
        if r.get("status") != "ok":
            skipped["not-ok"] = skipped.get("not-ok", 0) + 1
            continue
        if "roofline" not in r:
            skipped["no-roofline-section"] = \
                skipped.get("no-roofline-section", 0) + 1
            continue
        rf, acc = r["roofline"], r["accounting"]
        dom = rf["dominant"].replace("_s", "")
        note = {
            "compute": "MXU-bound: fuse/alignment wins only",
            "memory": "HBM-bound: fewer bytes/act re-reads (fusion, dtype, remat policy)",
            "collective": "ICI-bound: reshard/overlap collectives",
        }[dom]
        rows.append(
            f"| {arch} | {shape} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | {dom} | {rf['roofline_fraction']:.3f} | "
            f"{acc['useful_ratio']:.2f} | {note} |")
    if skipped:
        parts = ", ".join(f"{n} {reason}"
                          for reason, n in sorted(skipped.items()))
        rows.append(f"\n{sum(skipped.values())} cell(s) not shown: {parts}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    cells = load(args.out)
    ok = sum(1 for r in cells.values() if r.get("status") == "ok")
    print(f"## Dry-run matrix ({ok}/{len(cells)} cells ok)\n")
    print("\n".join(dryrun_table(cells)))
    print("\n## Roofline (single-pod 16x16, per-cell three terms)\n")
    print("\n".join(roofline_table(cells)))


if __name__ == "__main__":
    main()
