"""Collective-byte extraction from compiled HLO text (§Roofline sources).

cost_analysis has no collective term, so we parse the post-SPMD HLO: sum the
operand bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Ops inside while-loop bodies are counted once by the text,
so the caller supplies `loop_factor` (the known scan trip count — layers) and
we scale ops that live in while-body computations accordingly; the accounting
configs used for the roofline are loop-free, making the scaling exact there.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'f32[128,512]{1,0}' or a tuple
    '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}:{v/1e6:.1f}MB(x{self.count_by_kind[k]})"
                 for k, v in sorted(self.bytes_by_kind.items()) if v]
        return " ".join(parts) or "none"


def _split_computations(hlo: str) -> List[Tuple[str, List[str]]]:
    """(computation_name, lines) blocks from HLO text."""
    comps: List[Tuple[str, List[str]]] = []
    cur_name = None
    cur: List[str] = []
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^%?([\w\.\-]+)\s*(\([^)]*\))?.*\{$", stripped)
        if m and ("->" in stripped or stripped.endswith("{")) and not stripped.startswith("ROOT"):
            if cur_name is not None:
                comps.append((cur_name, cur))
            cur_name = m.group(1)
            cur = []
        elif stripped == "}":
            if cur_name is not None:
                comps.append((cur_name, cur))
            cur_name, cur = None, []
        elif cur_name is not None:
            cur.append(stripped)
    if cur_name is not None and cur:
        comps.append((cur_name, cur))
    return comps


def collective_stats(hlo: str, loop_factor: float = 1.0) -> CollectiveStats:
    """Sum collective operand bytes; ops inside while-body computations are
    scaled by loop_factor."""
    # find computations used as while bodies/conditions
    loop_comps = set()
    for m in re.finditer(r"(?:body|condition)=%?([\w\.\-]+)", hlo):
        loop_comps.add(m.group(1))

    bytes_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}

    for comp_name, lines in _split_computations(hlo):
        in_loop = any(comp_name.startswith(lc) or lc.startswith(comp_name)
                      for lc in loop_comps)
        factor = loop_factor if in_loop else 1.0
        for line in lines:
            for kind in _COLLECTIVES:
                # match op kind at the instruction position: "x = shape kind("
                if re.search(rf"=\s*[^=]*\b{kind}(-start|-done)?\(", line):
                    if f"{kind}-done" in line:
                        continue          # counted at -start
                    # operand bytes: the instruction's result shape equals the
                    # transferred payload for these collectives
                    eq = line.split("=", 1)
                    shape_part = eq[1] if len(eq) > 1 else line
                    nbytes = _shape_bytes(shape_part.split(f"{kind}")[0])
                    bytes_by_kind[kind] += int(nbytes * factor)
                    count_by_kind[kind] += 1
                    break
    return CollectiveStats(bytes_by_kind, count_by_kind)
