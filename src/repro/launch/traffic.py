"""Traffic-replay serving harness: seeded request generation + a
virtual-clock continuous-batching loop over the real planner (jax-free).

This is where "millions of users" becomes a measured number. A deterministic
generator emits Poisson arrivals for multiple concurrent tenants (each its
own model config, all sharing one planner cache and one engine), and the
simulation loop replays them through the REAL serving dispatch path: every
admitted batch's GEMM workload (`deploy.planner.model_workload` at the
batch's M) is resolved through `Planner.plan_cached` — exact hit, bucketed
transfer, online analytic tune, or fallback — exactly as `models.matmul.pmm`
would at trace time. Only the *clock* is virtual: per-batch service time is
the resolved plans' predicted cost plus explicit, configurable charges for
the things live traffic actually pays when the shape stream fragments
(per-new-shape compile, online-tune latency, transfer pricing, auto-fallback
penalty). Everything else — bucketing legality, transfer rejection on
ragged M, analytic shortlist pricing — is the production code deciding.

The admission policy under test is `deploy.batcher.ContinuousBatcher`:
bucket-aware admission keeps batched Ms on the warmed pow-2 pool; the
naive-FIFO baseline fragments. `benchmarks/serving_bench.py` runs both on
the same seeded trace and asserts the bucket-aware win; `launch/serve.py
--traffic` replays a trace against the live routed `pmm` path on a real
mesh (each distinct GEMM the replay dispatches is executed once, trace-time
semantics) and embeds the serving section in its run report.

SLO accounting: each request's deadline is `arrival + slo_ttft_s +
gen_len * slo_per_token_s` (from its tenant's spec). Goodput counts only
the tokens of requests that met their deadline; p50/p99 latency and TTFT
come from the run's `MetricsRegistry` histograms. docs/serving.md documents
the traffic model, the admission policy, and every serving-section field.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.deploy.batcher import (Batch, BatchPolicy, ContinuousBatcher,
                                  Request, bucket_pool, decode_m)
from repro.deploy.planner import model_workload
from repro.obs.metrics import MetricsRegistry

PHASES = ("prefill", "decode")
PROVENANCES = ("hit", "bucketed", "analytic", "fallback")


# ---------------------------------------------------------------------------
# Trace generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's arrival process + SLO contract."""
    name: str
    arch: str = "gemma-2b"            # registry arch backing this tenant
    rate_rps: float = 50.0            # Poisson arrival rate
    n_requests: int = 16
    prompt_lens: Tuple[int, ...] = (5, 9, 13, 17)
    gen_lens: Tuple[int, ...] = (2, 3, 5)
    start_s: float = 0.0
    slo_ttft_s: float = 0.5           # time-to-first-token budget
    slo_per_token_s: float = 0.1      # per-decode-token budget


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """A reproducible traffic trace: seed + tenant specs."""
    seed: int = 0
    tenants: Tuple[TenantSpec, ...] = (TenantSpec(name="tenant0"),)

    def max_rows(self, policy: BatchPolicy) -> int:
        """Upper bound on any admitted batch's token rows under `policy`
        (prefill: max_batch largest prompts; decode: max_batch sequences) —
        what `warm_pool` sizes the warmed bucket ladder to."""
        top = max(max(t.prompt_lens) for t in self.tenants)
        return max(policy.max_batch * top, policy.max_batch)


def generate_trace(cfg: TrafficConfig) -> List[Request]:
    """The deterministic seeded trace: same config -> identical request list.

    Each tenant draws from its own `random.Random(f"{seed}:{name}")` stream
    (string seeding is sha512-based and platform-stable), so adding a tenant
    never perturbs another tenant's arrivals. Requests are merged by arrival
    time (ties broken by tenant declaration order) and assigned global rids
    in that order.
    """
    drawn = []
    for t_idx, spec in enumerate(cfg.tenants):
        rng = random.Random(f"{cfg.seed}:{spec.name}")
        now = spec.start_s
        slo = spec.slo_ttft_s
        for i in range(spec.n_requests):
            now += rng.expovariate(spec.rate_rps)
            prompt = rng.choice(spec.prompt_lens)
            gen = rng.choice(spec.gen_lens)
            drawn.append((now, t_idx, i, spec.name, prompt, gen,
                          slo + gen * spec.slo_per_token_s))
    drawn.sort(key=lambda r: (r[0], r[1], r[2]))
    return [Request(rid=rid, tenant=name, arrival_s=now, prompt_len=prompt,
                    gen_len=gen, slo_s=slo)
            for rid, (now, _, _, name, prompt, gen, slo) in enumerate(drawn)]


# ---------------------------------------------------------------------------
# Virtual-clock accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingCosts:
    """Virtual charges for the real prices of shape fragmentation.

    Plan-predicted execution time is the cost model's number; these cover
    the host-side work around it. All in virtual seconds, all deterministic.
    """
    # per-batch launch overhead (host dispatch of one engine step).
    step_overhead_s: float = 1e-4
    # charged ONCE per GEMM shape the engine has never executed (XLA
    # compiles each distinct shape once; the warmed pool is pre-compiled).
    compile_s: float = 0.05
    # charged when a shape first resolves via the online analytic tune.
    online_tune_s: float = 2e-3
    # charged when a shape first resolves via a bucketed transfer.
    transfer_s: float = 5e-4
    # a fallback (no plan) runs the auto dataflow: its time is the shape's
    # roofline floor times this penalty (an untuned collective placement).
    fallback_penalty: float = 3.0


@dataclasses.dataclass
class RequestRecord:
    """Per-request accounting the SLO summary is computed from."""
    rid: int
    tenant: str
    arrival_s: float
    prompt_len: int
    gen_len: int
    slo_s: float
    ttft_s: float = math.nan
    done_s: float = math.nan

    @property
    def tokens(self) -> int:
        return self.prompt_len + self.gen_len

    @property
    def latency_s(self) -> float:
        return self.done_s - self.arrival_s

    @property
    def met(self) -> bool:
        return self.latency_s <= self.slo_s


def slo_summary(records: Sequence[RequestRecord],
                makespan_s: float) -> Dict[str, float]:
    """Goodput/deadline arithmetic over completed request records.

    Goodput counts only the tokens (prompt + generated) of requests that
    finished within their SLO deadline; throughput counts everything.
    """
    met = [r for r in records if r.met]
    good = sum(r.tokens for r in met)
    total = sum(r.tokens for r in records)
    n = len(records)
    span = max(makespan_s, 1e-12)
    return {
        "requests": n,
        "met": len(met),
        "missed": n - len(met),
        "deadline_miss_rate": (n - len(met)) / n if n else 0.0,
        "good_tokens": good,
        "total_tokens": total,
        "goodput_tps": good / span,
        "throughput_tps": total / span,
    }


@dataclasses.dataclass
class ServingResult:
    """Everything one simulated replay measured."""
    policy: BatchPolicy
    records: List[RequestRecord]
    per_phase: Dict[str, Dict[str, int]]
    batches: int
    cold_shapes: int       # shapes that paid the virtual compile charge
    distinct_shapes: int   # distinct GEMM shapes the replay dispatched
    makespan_s: float
    metrics: MetricsRegistry

    @property
    def dispatches(self) -> int:
        return sum(sum(c.values()) for c in self.per_phase.values())

    @property
    def resolve_rate(self) -> float:
        n = self.dispatches
        resolved = n - sum(c["fallback"] for c in self.per_phase.values())
        return resolved / n if n else 0.0


def _phase_section(counts: Dict[str, int]) -> Dict[str, float]:
    total = sum(counts.values())
    resolved = total - counts["fallback"]
    return dict(counts,
                dispatches=total,
                hit_rate=counts["hit"] / total if total else 0.0,
                resolve_rate=resolved / total if total else 0.0)


def serving_section(result: ServingResult) -> Dict[str, object]:
    """The run report's `serving` section (and BENCH_serving's per-run
    record): SLO summary + tail latencies + admission/planner accounting.
    Field-by-field reference in docs/serving.md."""
    lat = result.metrics.histogram("serving.latency_s").to_dict()
    ttft = result.metrics.histogram("serving.ttft_s").to_dict()
    util = result.metrics.histogram("serving.batch_utilization").to_dict()
    out: Dict[str, object] = {"policy": result.policy.mode}
    out.update(slo_summary(result.records, result.makespan_s))
    out.update(
        p50_latency_s=lat["p50"], p99_latency_s=lat["p99"],
        p50_ttft_s=ttft["p50"], p99_ttft_s=ttft["p99"],
        makespan_s=result.makespan_s,
        batches=result.batches,
        cold_shapes=result.cold_shapes,
        distinct_shapes=result.distinct_shapes,
        mean_batch_utilization=util["mean"],
        resolve_rate=result.resolve_rate,
        per_phase={phase: _phase_section(counts)
                   for phase, counts in result.per_phase.items()},
    )
    return out


# ---------------------------------------------------------------------------
# Warm pool
# ---------------------------------------------------------------------------

def warm_pool(planner, cfgs: Dict[str, object], policy: BatchPolicy,
              max_rows: int) -> List[object]:
    """Batch-tune every GEMM shape the bucket policy can emit for `cfgs`'
    workloads up to `max_rows` token rows (prefill AND decode at each pow-2
    M of the bucket ladder). Returns the warmed shape list — the sim treats
    these as pre-compiled (`precompiled=` arg), mirroring a real server's
    startup warm-up."""
    shapes: List[object] = []
    for m in bucket_pool(max_rows, policy):
        for cfg in cfgs.values():
            shapes += model_workload(cfg, batch=m, seq=1, kind="prefill")
            shapes += model_workload(cfg, batch=m, seq=1, kind="decode")
    shapes = list(dict.fromkeys(shapes))
    planner.batch_tune(shapes)
    return shapes


# ---------------------------------------------------------------------------
# The virtual-clock replay loop
# ---------------------------------------------------------------------------

def _classify(plan) -> str:
    """Provenance class of a served plan — mirrors matmul.lookup_plan."""
    source = getattr(plan, "source", "")
    return source if source in ("bucketed", "analytic") else "hit"


def _auto_floor_s(shape, hw, elem_bytes: int) -> float:
    """Roofline floor for an unplanned (auto) GEMM on `hw`."""
    return max(shape.flops() / hw.peak_flops,
               shape.min_bytes(elem_bytes) / hw.hbm.total_bw)


class _Engine:
    """One serial engine: batched prefill + round-robin decode rounds."""

    def __init__(self, trace, planner, cfgs, policy, costs, precompiled,
                 dispatch):
        self.trace = sorted(trace, key=lambda r: (r.arrival_s, r.rid))
        self.planner = planner
        self.cfgs = cfgs
        self.policy = policy
        self.costs = costs
        self.dispatch = dispatch
        self.batcher = ContinuousBatcher(policy)
        self.seen = set(precompiled)   # shapes that paid (or pre-paid) compile
        self.executed = set()          # distinct shapes this replay dispatched
        self.cold_shapes = 0
        self.pools: Dict[str, List[List]] = {}    # tenant -> [[rec, left]..]
        self.order: List[str] = []                # decode round-robin order
        self.rr = 0
        self.records: Dict[int, RequestRecord] = {}
        self.per_phase = {p: {k: 0 for k in PROVENANCES} for p in PHASES}
        self.metrics = MetricsRegistry()
        self.batches = 0
        self.prefer = "prefill"
        self.now = 0.0
        self.idx = 0

    # -- work selection ------------------------------------------------------

    def _deliver(self) -> None:
        while self.idx < len(self.trace) \
                and self.trace[self.idx].arrival_s <= self.now:
            req = self.trace[self.idx]
            self.batcher.submit(req)
            self.records[req.rid] = RequestRecord(
                rid=req.rid, tenant=req.tenant, arrival_s=req.arrival_s,
                prompt_len=req.prompt_len, gen_len=req.gen_len,
                slo_s=req.slo_s)
            self.idx += 1

    def _decode_tenant(self) -> Optional[str]:
        live = [t for t in self.order if self.pools.get(t)]
        if not live:
            return None
        tenant = live[self.rr % len(live)]
        self.rr += 1
        return tenant

    def _next_batch(self) -> Optional[Batch]:
        phases = (("prefill", "decode") if self.prefer == "prefill"
                  else ("decode", "prefill"))
        for phase in phases:
            if phase == "prefill":
                batch = self.batcher.next_prefill(self.now)
                if batch is not None:
                    self.prefer = "decode"
                    return batch
            else:
                tenant = self._decode_tenant()
                if tenant is not None:
                    self.prefer = "prefill"
                    return self._decode_round(tenant)
        return None

    def _decode_round(self, tenant: str) -> Batch:
        pool = self.pools[tenant]
        served = pool[:self.policy.max_batch]
        reqs = tuple(entry[0] for entry in served)
        rows = len(served)
        return Batch(tenant=tenant, phase="decode", requests=reqs,
                     rows=rows, m=decode_m(rows, self.policy))

    # -- pricing -------------------------------------------------------------

    def _serve(self, batch: Batch) -> float:
        cfg = self.cfgs[batch.tenant]
        shapes = model_workload(cfg, batch=batch.m, seq=1, kind=batch.phase)
        dt = self.costs.step_overhead_s
        for shape in shapes:
            plan = self.planner.plan_cached(shape)
            prov = "fallback" if plan is None else _classify(plan)
            self.per_phase[batch.phase][prov] += 1
            if plan is None:
                dt += self.costs.fallback_penalty * _auto_floor_s(
                    shape, self.planner.hw, self.planner.elem_bytes)
            else:
                dt += plan.report.total_time
            if shape not in self.executed:
                # real-dispatch hook: once per distinct shape (trace-time
                # semantics — shapes are static under jit), warmed or not
                self.executed.add(shape)
                if self.dispatch is not None:
                    self.dispatch(shape, batch.phase)
            if shape not in self.seen:
                self.seen.add(shape)
                self.cold_shapes += 1
                dt += self.costs.compile_s
                if prov == "analytic":
                    dt += self.costs.online_tune_s
                elif prov == "bucketed":
                    dt += self.costs.transfer_s
        self.metrics.observe("serving.batch_utilization", batch.utilization)
        self.metrics.observe(f"serving.batch_service_s.{batch.phase}", dt)
        self.batches += 1
        return dt

    # -- completions ---------------------------------------------------------

    def _finish(self, batch: Batch, done: float) -> None:
        if batch.phase == "prefill":
            for req in batch.requests:
                rec = self.records[req.rid]
                rec.ttft_s = done - req.arrival_s
                self.metrics.observe("serving.ttft_s", rec.ttft_s)
                if req.gen_len == 0:
                    self._complete(rec, done)
                    continue
                if req.tenant not in self.pools:
                    self.pools[req.tenant] = []
                    self.order.append(req.tenant)
                self.pools[req.tenant].append([req, req.gen_len])
            return
        pool = self.pools[batch.tenant]
        served, rest = pool[:len(batch.requests)], pool[len(batch.requests):]
        alive = []
        for entry in served:
            entry[1] -= 1
            if entry[1] <= 0:
                self._complete(self.records[entry[0].rid], done)
            else:
                alive.append(entry)
        # survivors rotate to the tail so an over-full pool round-robins
        self.pools[batch.tenant] = rest + alive

    def _complete(self, rec: RequestRecord, done: float) -> None:
        rec.done_s = done
        self.metrics.observe("serving.latency_s", rec.latency_s)

    # -- the loop ------------------------------------------------------------

    def run(self) -> ServingResult:
        while True:
            self._deliver()
            batch = self._next_batch()
            if batch is None:
                horizons = []
                if self.idx < len(self.trace):
                    horizons.append(self.trace[self.idx].arrival_s)
                decision = self.batcher.next_decision_s()
                if decision is not None:
                    horizons.append(decision)
                if not horizons:
                    break                      # drained: no work anywhere
                self.now = max(self.now, min(horizons))
                continue
            self.now += self._serve(batch)
            self._finish(batch, self.now)
        assert len(self.records) == len(self.trace)
        assert all(math.isfinite(r.done_s) for r in self.records.values()), \
            "requests lost by the batching loop"
        return ServingResult(
            policy=self.policy,
            records=[self.records[r.rid] for r in self.trace],
            per_phase=self.per_phase, batches=self.batches,
            cold_shapes=self.cold_shapes,
            distinct_shapes=len(self.executed), makespan_s=self.now,
            metrics=self.metrics)


def simulate(trace: Sequence[Request], planner, cfgs: Dict[str, object],
             policy: BatchPolicy = BatchPolicy(),
             costs: ServingCosts = ServingCosts(),
             precompiled: Iterable = (),
             dispatch: Optional[Callable] = None) -> ServingResult:
    """Replay `trace` through the continuous batcher against `planner`.

    `cfgs` maps tenant name -> model config (duck-typed, jax-free).
    `precompiled` seeds the engine's seen-shape set (the warmed pool — those
    shapes never pay the virtual compile charge). `dispatch(shape, phase)`,
    when given, is invoked once per cold shape — `serve --traffic` uses it
    to execute the real routed `pmm` on the mesh (trace-time semantics: one
    real execution per distinct shape).
    """
    return _Engine(trace, planner, cfgs, policy, costs, precompiled,
                   dispatch).run()
