"""Production mesh construction (defined as functions so importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """One TPU v5e pod = a 16x16 chip grid (the SoftHier tile grid of the
    DESIGN.md mapping); multi_pod adds a leading pure-DP 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has — smoke tests and examples."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
