import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e + the §Roofline data source).

For one (arch x shape x mesh) cell:
1. FULL config: jit(step).lower(**input_specs).compile() on the production
   mesh — memory_analysis() proves the sharded program fits; the compile
   itself proves the collective schedule is coherent. Layer groups lower as
   scans (small HLO).
2. ACCOUNTING configs (1 and 2 layer-units, loop-free via accounting_mode):
   cost_analysis() + collective-byte parsing give exact per-unit FLOPs/bytes,
   extrapolated linearly to the full depth (XLA counts while bodies once —
   verified — so the full-config numbers cannot be read directly).

Each invocation handles one cell and appends JSON to --out (crash isolation;
the sweep script loops and caches).

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k \
      [--multi-pod] [--skip-accounting] --out results/dryrun

With --plan-cache the cell also cross-validates the deployment-plan
workload (record-only). Adding --route compiles the cell with plan routing
ON — every model matmul dispatches through its tuned dataflow's shard_map
collectives on the 16x16 production mesh and the JSON reports per-reason
lowering fallbacks (the ROADMAP routed-compile proof; pair with
--skip-accounting to keep the measurement to the one routed compile).
--route-dataflows restricts the warm-up's candidate search, e.g.
`--route-dataflows systolic_over_summa` proves the Fig. 6c outer-systolic
mode executes on the production mesh (see docs/dataflows.md).
--calibrate closes the measurement loop first: every executable mode runs
on a --plan-grid mesh of local devices, a CalibrationProfile is fitted and
persisted into --plan-cache, the warm-up tunes with the measured cost
model, and the JSON gains a 'calibration' section (fit quality + how many
of this cell's tuning decisions the calibration flipped). See
docs/plan-lifecycle.md "Calibration".

With --plan-cache the cell additionally installs the structured dispatch
tracer (repro.obs) and writes, next to the cell JSON: <tag>.run_report.json
(the versioned machine-readable report CI asserts on — routing counters,
per-dispatch plan provenance, workload coverage, calibration fit,
predicted-vs-measured drift) and <tag>.trace.json (Chrome trace-event
spans, loadable at ui.perfetto.dev). See docs/observability.md.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config, list_archs
from repro.launch import flops as flops_lib
from repro.launch.hlo_stats import collective_stats
from repro.launch.input_specs import input_specs, param_shapes, cache_shapes
from repro.launch.mesh import make_production_mesh
from repro.models import accounting
from repro.models.common import ModelConfig
from repro.models.model import decode_step, forward
from repro.optim import adamw
from repro.parallel.spec_rules import (batch_spec, cache_shardings, dp_axes,
                                       param_shardings)
from repro.train.steps import make_serve_step, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# accounting-unit reduction per arch family
# ---------------------------------------------------------------------------

def accounting_configs(cfg: ModelConfig) -> Tuple[ModelConfig, ModelConfig,
                                                  float, int, int]:
    """(cfg_small, cfg_big, units_full, units_small, units_big): linear
    extrapolation F_full = F_s + (units_full - u_s)/(u_b - u_s) * (F_b - F_s)."""
    if cfg.is_encoder_decoder:
        c1 = dataclasses.replace(cfg, n_layers=1, n_encoder_layers=1)
        c2 = dataclasses.replace(cfg, n_layers=2, n_encoder_layers=2)
        return c1, c2, cfg.n_layers, 1, 2
    if cfg.block_pattern == "mamba2_hybrid":
        per = cfg.hybrid_attn_every
        c1 = dataclasses.replace(cfg, n_layers=per)
        c2 = dataclasses.replace(cfg, n_layers=2 * per)
        return c1, c2, cfg.n_layers / per, 1, 2
    if cfg.block_pattern == "xlstm":
        per = cfg.slstm_every
        c1 = dataclasses.replace(cfg, n_layers=per)
        c2 = dataclasses.replace(cfg, n_layers=2 * per)
        return c1, c2, cfg.n_layers // per, 1, 2
    if cfg.n_experts and cfg.n_dense_layers:
        # keep the dense layer in the base; delta = one MoE layer
        c1 = dataclasses.replace(cfg, n_layers=cfg.n_dense_layers + 1)
        c2 = dataclasses.replace(cfg, n_layers=cfg.n_dense_layers + 2)
        return c1, c2, cfg.n_layers - cfg.n_dense_layers, 1, 2
    c1 = dataclasses.replace(cfg, n_layers=1)
    c2 = dataclasses.replace(cfg, n_layers=2)
    return c1, c2, cfg.n_layers, 1, 2


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

MICROBATCHES = int(os.environ.get("DIT_MICROBATCHES", "1"))


def build_lowered(cfg: ModelConfig, shape_name: str, mesh,
                  donate: bool = True):
    """Lower the cell's step function with production shardings."""
    specs = input_specs(cfg, shape_name)
    kind = specs["kind"]
    pshapes = param_shapes(cfg)
    pshard = param_shardings(pshapes, mesh)
    bspec = NamedSharding(mesh, batch_spec(mesh))

    if kind == "train":
        opt = adamw.AdamWConfig()
        ostate_shapes = jax.eval_shape(lambda p: adamw.init(p), pshapes)
        oshard = jax.tree.map(
            lambda l: NamedSharding(mesh, P()) if l.ndim == 0 else None,
            ostate_shapes)
        # moments follow the param shardings; scalar step replicated
        oshard = adamw.AdamWState(
            step=NamedSharding(mesh, P()),
            mu=param_shardings(ostate_shapes.mu, mesh),
            nu=param_shardings(ostate_shapes.nu, mesh))
        step_raw = make_train_step(cfg, opt, microbatches=MICROBATCHES,
                                   compress_grads=False)

        def train_fn(params, opt_state, batch):
            p, o, _, m = step_raw(params, opt_state, None, batch)
            return p, o, m["loss"]

        bshard = jax.tree.map(lambda l: bspec, specs["inputs"])
        fn = jax.jit(
            train_fn,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
            donate_argnums=(0, 1) if donate else ())
        return fn.lower(pshapes, ostate_shapes, specs["inputs"]), specs

    if kind == "prefill":
        # serving semantics: prefill fills the cache and emits ONLY the
        # last position's logits (§Perf iteration 12 — returning the full
        # (B,S,V) fp32 logits cost seamless 135 GB/device).
        def prefill_fn(params, tokens, *extra):
            kwargs = {}
            i = 0
            if cfg.frontend == "vision_stub":
                kwargs["prefix_embeds"] = extra[i]; i += 1
            if cfg.is_encoder_decoder:
                kwargs["encoder_embeds"] = extra[i]; i += 1
            hidden = forward(params, tokens, cfg, remat=False,
                             return_hidden=True, **kwargs)
            from repro.models.model import lm_head_weight
            return (hidden[:, -1] @ lm_head_weight(params, cfg)
                    ).astype(jnp.float32)

        args = [pshapes, specs["tokens"]]
        in_sh = [pshard, bspec]
        for key in ("prefix_embeds", "encoder_embeds"):
            if key in specs:
                args.append(specs[key])
                in_sh.append(bspec)
        vocab_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        fn = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                     out_shardings=NamedSharding(
                         mesh, P(dp_axes(mesh), vocab_ax)))
        return fn.lower(*args), specs

    # decode
    cshapes = specs["caches"]
    cshard = cache_shardings(cshapes, mesh, cfg, specs["batch"])
    tok_sh = NamedSharding(
        mesh, batch_spec(mesh) if specs["batch"] % _dp_size(mesh) == 0
        else P(None, None))

    def decode_fn(params, caches, tokens, position, *extra):
        enc = extra[0] if extra else None
        logits, new_caches = decode_step(params, caches, tokens, position,
                                         cfg, encoder_out=enc)
        return logits, new_caches

    args = [pshapes, cshapes, specs["tokens"], specs["position"]]
    in_sh = [pshard, cshard, tok_sh, NamedSharding(mesh, P())]
    if "encoder_out" in specs:
        args.append(specs["encoder_out"])
        in_sh.append(tok_sh if specs["batch"] % _dp_size(mesh) == 0
                     else NamedSharding(mesh, P(None, None, None)))
    fn = jax.jit(decode_fn, in_shardings=tuple(in_sh),
                 out_shardings=(NamedSharding(mesh, P()), cshard),
                 donate_argnums=(1,) if donate else ())
    return fn.lower(*args), specs


def _dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _cost_analysis(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() normalized to one dict: jax returns a plain
    dict for most executables but a per-module list for some partitioned
    programs (observed with routed shard_map matmuls in the step)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


# ---------------------------------------------------------------------------
# per-cell run
# ---------------------------------------------------------------------------

def calibrate_plan_cache(plan_cache: str, plan_grid, reps: int = 1
                         ) -> Dict[str, Any]:
    """Fit the SoftHier cost model to this host's measured mode efficiency.

    Runs `sim.calibrate.measure_modes` on a `plan_grid` mesh carved out of
    the local devices (every executable mode over the GEMM grid, lowering
    asserted clean), least-squares-fits a `CalibrationProfile`, and persists
    it NEXT TO THE PLANS keyed by the pod-view hardware fingerprint — the
    same profile `deploy.warmup.build_planner` auto-loads, so every later
    warm-up from this cache dir tunes with the measured cost model.
    Returns the JSON `calibration` section (fit stats + measurement count).
    """
    import jax

    from repro.hw.config import tpu_pod_as_accelerator
    from repro.sim import calibrate as cal

    rows, cols = plan_grid
    if rows != cols or rows < 4:
        # the mode-case table needs a square grid for the cannon ring and
        # >= 4x4 for a non-degenerate outer ring of 2x2 inner groups —
        # fail with the requirement, not a deep clean-lowering assertion
        raise ValueError(
            f"--calibrate requires a square --plan-grid of at least 4x4 "
            f"(every executable mode must lower cleanly on the "
            f"measurement mesh); got {rows}x{cols}")
    hw = tpu_pod_as_accelerator(tuple(plan_grid))
    mesh = jax.make_mesh(tuple(plan_grid), ("data", "model"))
    # the profile persisted by the PREVIOUS calibration run (if any): the
    # fresh measurements below, compared against ITS predictions, quantify
    # how far the machine drifted since it was fitted
    prior = cal.load_profile(plan_cache, hw)
    t0 = time.time()
    profile, samples = cal.calibrate_mesh(hw, mesh, reps=reps)
    path = cal.save_profile(plan_cache, profile)
    cal.save_samples(plan_cache, profile.hw_digest, samples)
    print(f"calibration: {profile.describe()} from {len(samples)} "
          f"measurements in {time.time()-t0:.1f}s -> {path}", flush=True)
    out = {
        "profile": profile.to_dict(),
        "profile_digest": profile.digest(),
        "samples": len(samples),
        "fit_ok": profile.fit_ok,
        "rank_agreement_before": profile.rank_agreement_before,
        "rank_agreement_after": profile.rank_agreement_after,
        "picks_measured_ratio": profile.picks_measured_ratio,
    }
    if prior is not None:
        from repro.obs import DriftMonitor
        mon = DriftMonitor(prior)
        mon.add_samples(samples)
        out["drift_vs_prior"] = mon.summary()
    return out


def calibration_rank_flips(planner, workload) -> Dict[str, Any]:
    """Re-tune the workload with and without the planner's profile and
    count schedules the calibrated ranking changed (fresh searches on both
    sides — the cache is not consulted, and BOTH searches enumerate the
    same dataflow space, so the report isolates the ranking effect of the
    measured scale factors from the trusted profile's search-space
    widening)."""
    from repro.core.autotuner import default_dataflows, tune
    from repro.sim.calibrate import is_trusted

    flips, flipped = 0, []
    shapes = list(dict.fromkeys(workload))
    out = {"workload_shapes": len(shapes), "trusted": True}
    if not is_trusted(planner.calibration):
        # an untrusted profile is defined to change no ranking (the tuner
        # ignores it), so the two searches below would be identical —
        # report the foregone conclusion without paying 2N candidate
        # searches
        return {**out, "trusted": False, "rank_flips": 0, "flipped": []}
    space = planner.dataflows or default_dataflows(planner.calibration)
    for shape in shapes:
        kw = dict(dataflows=space,
                  elem_bytes=planner.elem_bytes,
                  max_candidates=planner.max_candidates,
                  store_stage_options=planner.store_stage_options)
        try:
            base = tune(shape, planner.hw, **kw)
            calib = tune(shape, planner.hw, calibration=planner.calibration,
                         **kw)
        except RuntimeError:
            continue
        if base.schedule != calib.schedule:
            flips += 1
            flipped.append({"shape": [shape.m, shape.n, shape.k],
                            "analytical": base.schedule.describe(),
                            "calibrated": calib.schedule.describe()})
    return {**out, "rank_flips": flips, "flipped": flipped}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             skip_accounting: bool = False,
             plan_cache: str = "",
             plan_grid=(4, 4),
             route: bool = False,
             route_dataflows=None,
             calibrate: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    from repro.models import shard_ctx
    shard_ctx.set_mesh(mesh)   # pin activation layouts during tracing
    gemm_ctx = None
    calibration_out = None
    if plan_cache:
        # structured dispatch tracer: every pmm the cell traces emits a
        # provenance span; main() exports <tag>.trace.json + the run report
        from repro.obs import Tracer, set_tracer
        set_tracer(Tracer(process_name=f"dryrun.{arch}.{shape_name}"))
    if calibrate:
        # fit + persist BEFORE the planner is built so the warm-up below
        # already tunes with the measured cost model
        calibration_out = calibrate_plan_cache(plan_cache, plan_grid)
    if plan_cache:
        # Default: record-only gemm context — every pmm the cell traces is
        # logged so the JSON can cross-validate model_workload (and the
        # warmed plan cache) against the GEMMs this (arch x shape x mesh)
        # really runs, while the compile measures the untouched production
        # program. --route flips the context live: the cell's workload is
        # warmed into the planner and every model matmul compiles through
        # its tuned dataflow's shard_map collectives on the production mesh
        # (the ROADMAP "16x16 routed compile proof"), with per-reason
        # fallback counts in the JSON — no silent auto degrades.
        from repro.deploy.warmup import build_planner, warm_buckets
        planner = build_planner(plan_cache, plan_grid, max_candidates=12,
                                dataflows=route_dataflows)
        if route or calibration_out is not None:
            from repro.deploy import model_workload
            specs0 = input_specs(cfg, shape_name)
            workload = model_workload(cfg, specs0["batch"], specs0["seq"],
                                      kind=specs0["kind"], dp=_dp_size(mesh))
        if calibration_out is not None:
            # how many of this cell's tuning decisions the measured scale
            # factors actually changed (fresh searches both sides)
            calibration_out.update(calibration_rank_flips(planner, workload))
        if route:
            warm_buckets(planner, workload)
            planner.batch_tune(workload, allow_bucketed=True,
                               skip_illegal=route_dataflows is not None)
            gemm_ctx = shard_ctx.GemmContext(mesh=mesh, planner=planner)
        else:
            gemm_ctx = shard_ctx.GemmContext(mesh=None, planner=planner)
        shard_ctx.set_gemm_context(gemm_ctx)
    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "routed": bool(route),
    }
    if calibration_out is not None:
        out["calibration"] = calibration_out
    t0 = time.time()

    # 1. FULL config: compile + memory analysis
    lowered, specs = build_lowered(cfg, shape_name, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    out["full"] = {
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "argument_bytes_per_device": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes_per_device": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes_per_device": int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
    }
    # cost_analysis is PER-DEVICE on the partitioned module (verified
    # empirically); scale by n_chips for global numbers. Loop bodies are
    # counted once, hence the accounting configs below for the real terms.
    ca = _cost_analysis(compiled)
    out["full"]["hlo_flops_raw"] = float(ca.get("flops", 0.0)) * n_chips
    out["full"]["hlo_bytes_raw"] = float(ca.get("bytes accessed", 0.0)) * n_chips
    cs = collective_stats(compiled.as_text())
    out["full"]["collective_bytes_raw"] = cs.total_bytes * n_chips
    out["full"]["collectives"] = cs.summary()
    del compiled, lowered

    if gemm_ctx is not None:
        from repro.deploy import model_workload, workload_coverage
        observed = gemm_ctx.stats.observed_shapes()
        # dp matters: moe dispatch groups align to the mesh's DP axes, so
        # the predicted expert capacity must use this cell's mesh geometry
        predicted = model_workload(cfg, specs["batch"], specs["seq"],
                                   kind=specs["kind"], dp=_dp_size(mesh))
        cov = workload_coverage(predicted, observed)
        planner = gemm_ctx.planner
        resolved = sum(1 for s in observed
                       if planner.plan_cached(s) is not None)
        out["workload"] = {
            "observed": len(observed),
            "predicted": len(predicted),
            "covered": cov["covered"],
            "extra": [[s.m, s.n, s.k] for s in cov["extra"]],
            "missing": [[s.m, s.n, s.k] for s in cov["missing"]],
            "plan_resolved": resolved,
            "plan_resolve_rate": resolved / len(observed) if observed else 0.0,
        }
        if route:
            st = gemm_ctx.stats
            out["routing"] = {
                "modes": dict(sorted(st.modes.items())),
                "degrade_reasons": dict(sorted(st.degrades.items())),
                # degraded == landed on auto; reasons like non_square_systolic
                # or a scatter demotion still execute a tuned dataflow
                "degraded": st.modes.get("auto", 0),
                "silent_auto_degrades": st.silent_degrades,
                "hits": st.hits, "bucketed": st.bucketed,
                "fallback": st.fallback,
                "resolve_rate": st.resolve_rate,
            }

    if plan_cache:
        # predicted-vs-measured drift of the persisted calibration profile
        # against the persisted measurement samples (written next to it by
        # --calibrate; present on this run when --calibrate just ran, or
        # from an earlier calibration of the same cache dir)
        from repro.hw.config import tpu_pod_as_accelerator
        from repro.obs import DriftMonitor
        from repro.sim import calibrate as cal
        hw_pod = tpu_pod_as_accelerator(tuple(plan_grid))
        profile = cal.load_profile(plan_cache, hw_pod)
        samples = cal.load_samples(plan_cache, hw_pod)
        if profile is not None and samples:
            mon = DriftMonitor(profile)
            mon.add_samples(samples)
            out["drift"] = mon.summary()

    # 2. accounting configs for the roofline terms
    if not skip_accounting:
        c1, c2, units_full, u1, u2 = accounting_configs(cfg)
        vals = {}
        for tag, c in (("small", c1), ("big", c2)):
            with accounting.accounting_mode(specs["seq"]):
                low, _ = build_lowered(c, shape_name, mesh, donate=False)
                comp = low.compile()
            cai = _cost_analysis(comp)
            csi = collective_stats(comp.as_text())
            vals[tag] = {         # x n_chips: per-device -> global
                "flops": float(cai.get("flops", 0.0)) * n_chips,
                "bytes": float(cai.get("bytes accessed", 0.0)) * n_chips,
                "coll": float(csi.total_bytes) * n_chips,
            }
            del comp, low
        scale = (units_full - u1) / (u2 - u1)
        extr = {k: vals["small"][k] + scale * (vals["big"][k] - vals["small"][k])
                for k in ("flops", "bytes", "coll")}
        mf = flops_lib.model_flops(cfg, specs["batch"], specs["seq"], specs["kind"])
        extr["flops"] += mf["slstm_correction"]
        out["accounting"] = {
            "per_unit": vals, "units_full": units_full,
            "hlo_flops": extr["flops"], "hlo_bytes": extr["bytes"],
            "collective_bytes": extr["coll"],
            "model_flops": mf["total"],
            "model_flops_breakdown": mf,
            "useful_ratio": mf["total"] / extr["flops"] if extr["flops"] else 0.0,
        }
        # roofline terms (single-pod constants; per-chip)
        PEAK, HBM, ICI = 197e12, 819e9, 50e9 * 4   # bf16 peak, HBM bw, 4 links
        out["roofline"] = {
            "compute_s": extr["flops"] / (n_chips * PEAK),
            "memory_s": extr["bytes"] / (n_chips * HBM),
            "collective_s": extr["coll"] / (n_chips * ICI),
        }
        dom = max(out["roofline"], key=out["roofline"].get)
        out["roofline"]["dominant"] = dom
        tot = max(out["roofline"]["compute_s"], out["roofline"]["memory_s"],
                  out["roofline"]["collective_s"])
        out["roofline"]["roofline_fraction"] = (
            out["roofline"]["compute_s"] / tot if tot else 0.0)

    out["elapsed_s"] = round(time.time() - t0, 1)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-accounting", action="store_true")
    ap.add_argument("--plan-cache", default="",
                    help="warmed plan-cache dir; enables the record-only "
                         "gemm context + workload coverage report")
    ap.add_argument("--plan-grid", type=int, nargs=2, default=(4, 4),
                    metavar=("R", "C"),
                    help="pod grid the cache was warmed for (fingerprint)")
    ap.add_argument("--route", action="store_true",
                    help="compile with plan routing ON: warm the planner for "
                         "this cell's workload and dispatch every model "
                         "matmul through its tuned dataflow's collectives "
                         "on the production mesh (requires --plan-cache); "
                         "the JSON gains a 'routing' section with "
                         "per-reason fallback counts")
    ap.add_argument("--route-dataflows", nargs="+", default=None,
                    metavar="DF",
                    help="restrict the warm-up's candidate search to these "
                         "schedule dataflows (e.g. systolic_over_summa to "
                         "prove the Fig. 6c outer-systolic mode on the "
                         "production mesh); shapes with no legal restricted "
                         "schedule stay unplanned and dispatch as auto "
                         "fallbacks")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the SoftHier cost model to measured mode "
                         "efficiency before warming: run every executable "
                         "mode on a --plan-grid mesh of local devices, "
                         "least-squares-fit per-resource scale factors, "
                         "persist the profile into --plan-cache (keyed by "
                         "hw fingerprint, auto-loaded by later warm-ups), "
                         "re-tune this cell's workload and report rank-flip "
                         "counts in the JSON 'calibration' section")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    if args.route and not args.plan_cache:
        ap.error("--route requires --plan-cache")
    if args.route_dataflows and not args.route:
        ap.error("--route-dataflows requires --route")
    if args.calibrate and not args.plan_cache:
        ap.error("--calibrate requires --plan-cache (the profile persists "
                 "next to the plans it calibrates)")

    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{'mp' if args.multi_pod else 'sp'}"
    if args.route:
        tag += "__routed"
    path = os.path.join(args.out, tag + ".json")
    try:
        result = run_cell(args.arch, args.shape, args.multi_pod,
                          skip_accounting=args.skip_accounting,
                          plan_cache=args.plan_cache,
                          plan_grid=args.plan_grid,
                          route=args.route,
                          route_dataflows=args.route_dataflows,
                          calibrate=args.calibrate)
        result["status"] = "ok"
    except Exception as e:
        result = {"arch": args.arch, "shape": args.shape,
                  "multi_pod": args.multi_pod, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-3000:]}
    with open(path, "w") as f:
        json.dump(result, f, indent=1)

    # observability artifacts alongside the cell JSON: the versioned run
    # report (what CI asserts on) + the Perfetto-loadable dispatch trace.
    # launch/report.py skips both suffixes when globbing cells.
    from repro.models import shard_ctx
    from repro.obs import build_run_report, get_tracer, write_run_report
    ctx = shard_ctx.get_gemm_context()
    tracer = get_tracer()
    if ctx is not None or tracer is not None:
        run_report = build_run_report(
            "dryrun",
            stats=ctx.stats.to_dict() if ctx is not None else None,
            workload=result.get("workload"),
            drift=result.get("drift"),
            calibration=result.get("calibration"),
            tracer=tracer,
            extra={"arch": args.arch, "shape": args.shape,
                   "multi_pod": args.multi_pod, "routed": args.route,
                   "status": result["status"]})
        rr_path = write_run_report(
            os.path.join(args.out, tag + ".run_report.json"), run_report)
        print(f"run report -> {rr_path}")
        if tracer is not None:
            print(f"trace -> "
                  f"{tracer.write(os.path.join(args.out, tag + '.trace.json'))}")

    print(json.dumps({k: v for k, v in result.items() if k != "traceback"},
                     indent=1))
    if result["status"] != "ok":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
