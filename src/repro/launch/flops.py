"""Analytic model-FLOPs (the 6ND side of the §Roofline MODEL_FLOPS ratio).

MODEL_FLOPS uses the standard convention: 6 * N * D for training (fwd 2ND +
bwd 4ND) and 2 * N_active * D for inference, over ACTIVE parameters (MoE:
shared + top-k experts only; embedding table excluded, LM head included).
Attention-score FLOPs are added explicitly (they are not in N*D):
12 * B * S^2 * H * hd per layer trained (4 matmul-equivalents x fwd+bwd
factor 3), 4 * B * S^2 * H * hd for prefill, 4 * B * S * H * hd per decoded
token against an S-long cache. The sLSTM recurrent matvec (which the HLO
accounting cannot see inside its time scan) is also computed here.
"""
from __future__ import annotations

from typing import Dict

from repro.models.common import ModelConfig


def _embedding_params(cfg: ModelConfig) -> int:
    n = cfg.vocab * cfg.d_model
    return n


def active_params_excl_embed(cfg: ModelConfig) -> int:
    n = cfg.active_param_count() - _embedding_params(cfg)
    if not cfg.tie_embeddings:
        pass  # lm_head stays counted (it is a real matmul)
    return max(n, 0)


def _attn_score_flops(cfg: ModelConfig, b: int, s: int, kind: str) -> float:
    if cfg.block_pattern == "xlstm":
        # mLSTM chunked scores are linear-attention-like: S * chunk, not S^2
        from repro.models.ssm import CHUNK
        h = cfg.n_heads
        hd = 2 * cfg.d_model // h
        n_m = (cfg.n_layers // cfg.slstm_every) * (cfg.slstm_every - 1)
        per_tok = 4 * min(CHUNK, s) * h * hd
        mult = {"train": 3, "prefill": 1, "decode": 0}[kind]
        base = b * s * per_tok * n_m * mult
        # decode: recurrent update is O(hd^2) per head per token
        if kind == "decode":
            base = b * n_m * h * hd * hd * 6
        return base
    if cfg.block_pattern == "mamba2_hybrid":
        # SSD: O(S * chunk) within + O(S * N * P) state math; attention only
        # in the shared block (n_super applications)
        from repro.models.ssm import CHUNK
        n_attn = cfg.n_layers // cfg.hybrid_attn_every
        hd = cfg.hd
        att = 4 * b * s * s * cfg.n_heads * hd * n_attn
        d_inner = 2 * cfg.d_model
        n_mamba = cfg.n_layers
        ssd = b * s * (min(CHUNK, s) * 2 + 2 * cfg.ssm_state) * d_inner * 2 * n_mamba
        mult = {"train": 3, "prefill": 1, "decode": 1}[kind]
        if kind == "decode":
            att = 4 * b * s * cfg.n_heads * hd * n_attn        # 1 token vs cache
            ssd = b * 2 * cfg.ssm_state * d_inner * 2 * n_mamba
        return (att + ssd) * (3 if kind == "train" else 1)
    hd = cfg.hd if cfg.attn != "mla" else (cfg.nope_head_dim + cfg.rope_head_dim)
    layers = cfg.n_layers + (cfg.n_encoder_layers or 0)
    per = 4 * s * s * cfg.n_heads * hd     # qk + av, fwd
    if kind == "decode":
        per = 4 * s * cfg.n_heads * hd     # 1 query vs S cache
    mult = {"train": 3, "prefill": 1, "decode": 1}[kind]
    return b * per * layers * mult


def slstm_recurrent_flops(cfg: ModelConfig, b: int, s: int, kind: str) -> float:
    """In-time-scan recurrent matvecs invisible to loop-free HLO accounting."""
    if cfg.block_pattern != "xlstm":
        return 0.0
    h = cfg.n_heads
    hd = cfg.d_model // h
    n_s = cfg.n_layers // cfg.slstm_every
    per_step = 2 * h * hd * 4 * hd          # block-diag recurrence
    steps = s if kind != "decode" else 1
    mult = 3 if kind == "train" else 1
    return b * steps * per_step * n_s * mult


def model_flops(cfg: ModelConfig, batch: int, seq: int, kind: str) -> Dict[str, float]:
    n_active = active_params_excl_embed(cfg)
    tokens = batch * seq if kind != "decode" else batch
    base = {"train": 6, "prefill": 2, "decode": 2}[kind] * n_active * tokens
    attn = _attn_score_flops(cfg, batch, seq, kind)
    slstm = slstm_recurrent_flops(cfg, batch, seq, kind)
    return {"matmul": float(base), "attention": float(attn),
            "slstm_correction": float(slstm),
            "total": float(base) + float(attn) + float(slstm)}
