"""Training launcher.

Smoke scale (this host):   PYTHONPATH=src python -m repro.launch.train \
    --arch olmo-1b --smoke --steps 200 --batch 8 --seq 128
Production (a real pod):   same command without --smoke; the mesh comes from
    make_production_mesh() and params/optimizer are sharded by spec_rules.

Features: deterministic stateless data, microbatching, optional int8 gradient
compression on the DP all-reduce, atomic checkpoints + auto-resume, heartbeat
files, straggler logging — the full DESIGN.md §5 story.

At startup the deployment-plan cache is warmed for the training workload and
installed as the model stack's gemm context, so the forward/backward matmuls
route through `dit_gemm(exec_plan=...)` (all dataflow modes are scan-based
and reverse-differentiable). `--skip-plan-warmup` turns both off. The
shutdown routing line includes the executed-mode histogram and per-reason
degrade counts from the schedule->mesh lowering (repro.core.lower).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.runtime import LoopConfig, run_training
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import shard_ctx
from repro.models.model import init_params
from repro.obs import (Tracer, build_run_report, render_run_report,
                       set_tracer, write_run_report)
from repro.optim import adamw, compress
from repro.parallel.spec_rules import param_shardings
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + host mesh (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--run-report", default="results/train_run_report.json",
                    help="where to write the versioned run report "
                         "('' disables)")
    ap.add_argument("--trace", default="",
                    help="write a Perfetto-loadable Chrome trace here")
    from repro.deploy.warmup import add_plan_args
    add_plan_args(ap)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shard_ctx.set_mesh(mesh)

    gemm_ctx = None
    tracer = None
    if not args.skip_plan_warmup:
        from repro.deploy import model_workload
        from repro.deploy.warmup import build_planner, warm_buckets
        planner = build_planner(args.plan_cache, args.plan_grid,
                                args.plan_candidates)
        # dp: MoE dispatch groups align to the mesh's DP axes when the
        # activation-sharding context is installed (production runs)
        dp = 1
        if shard_ctx.get_mesh() is not None:
            for a in ("pod", "data"):
                if a in mesh.axis_names:
                    dp *= mesh.shape[a]
        workload = model_workload(cfg, args.batch, args.seq, kind="train",
                                  dp=dp)
        warm_buckets(planner, workload)
        # exact shapes: warm hits or cheap bucketed transfers, never a
        # second full search on top of the bucket tunes above
        planner.batch_tune(workload, allow_bucketed=True)
        gemm_ctx = shard_ctx.GemmContext(mesh=mesh, planner=planner)
        shard_ctx.set_gemm_context(gemm_ctx)
        tracer = Tracer(process_name=f"train.{cfg.name}")
        set_tracer(tracer)

    opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                            total_steps=args.steps)
    params = init_params(jax.random.PRNGKey(0), cfg)
    if not args.smoke:
        shardings = param_shardings(jax.eval_shape(lambda: params), mesh)
        params = jax.tree.map(jax.device_put, params, shardings)
    opt_state = adamw.init(params)
    comp_state = compress.init(params) if args.compress_grads else None

    raw = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches,
                                  compress_grads=args.compress_grads))

    def step_fn(state, batch):
        p, o, c = state
        p, o, c, m = raw(p, o, c, batch)
        return (p, o, c), m

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    t_last = [time.time()]

    def on_metrics(step, m):
        if step % args.log_every == 0:
            dt = time.time() - t_last[0]
            t_last[0] = time.time()
            toks = args.batch * args.seq * args.log_every
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm {float(m.get('grad_norm', 0)):.2f} "
                  f"tok/s {toks / max(dt, 1e-9):,.0f}", flush=True)

    run_training(step_fn, (params, opt_state, comp_state), data,
                 LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                            ckpt_dir=args.ckpt_dir),
                 make_batch_arrays=lambda b: {k: jnp.asarray(v)
                                              for k, v in b.items()},
                 on_metrics=on_metrics)
    if gemm_ctx is not None:
        from repro.launch.serve import load_drift
        drift = load_drift(args.plan_cache, args.plan_grid)
        report = build_run_report(
            "train", stats=gemm_ctx.stats.to_dict(), drift=drift,
            tracer=tracer,
            extra={"arch": cfg.name, "steps": args.steps,
                   "batch": args.batch, "seq": args.seq})
        for line in render_run_report(report):
            print(line)
        if args.run_report:
            write_run_report(args.run_report, report)
            print(f"run report: {args.run_report}")
        if args.trace and tracer is not None:
            tracer.write(args.trace)
            print(f"chrome trace: {args.trace}")
    print("done.")


if __name__ == "__main__":
    main()
