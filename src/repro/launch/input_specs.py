"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell — weak-
type-correct, shardable, zero allocation.

`train` cells lower train_step; `prefill` cells lower the prefill forward;
`decode` cells lower serve_step (ONE new token against a KV cache of
seq_len), per the brief. VLM cells add stub patch embeddings; enc-dec cells
add stub frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES
from repro.models.common import ModelConfig
from repro.models.model import decode_init, init_params


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def param_shapes(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(
        lambda: decode_init(param_shapes(cfg), cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """Returns {kind, batch/seq metadata, and the abstract inputs}."""
    spec = SHAPES[shape_name]
    b, s = spec["global_batch"], spec["seq_len"]
    kind = spec["kind"]
    out: Dict[str, Any] = {"kind": kind, "batch": b, "seq": s}

    if kind == "train":
        batch = {
            "tokens": sds((b, s), jnp.int32),
            "targets": sds((b, s), jnp.int32),
        }
        if cfg.frontend == "vision_stub":
            batch["prefix_embeds"] = sds((b, cfg.n_prefix, cfg.d_model),
                                         jnp.bfloat16)
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = sds((b, cfg.n_prefix, cfg.d_model),
                                          jnp.bfloat16)
        out["inputs"] = batch
    elif kind == "prefill":
        out["tokens"] = sds((b, s), jnp.int32)
        if cfg.frontend == "vision_stub":
            out["prefix_embeds"] = sds((b, cfg.n_prefix, cfg.d_model),
                                       jnp.bfloat16)
        if cfg.is_encoder_decoder:
            out["encoder_embeds"] = sds((b, cfg.n_prefix, cfg.d_model),
                                        jnp.bfloat16)
    elif kind == "decode":
        out["tokens"] = sds((b, 1), jnp.int32)
        out["position"] = sds((), jnp.int32)
        out["caches"] = cache_shapes(cfg, b, s)
        if cfg.is_encoder_decoder:
            out["encoder_out"] = sds((b, cfg.n_prefix, cfg.d_model),
                                     jnp.bfloat16)
    else:
        raise ValueError(kind)
    return out
