"""Dry-run sweep driver: every (arch x shape) cell on the single-pod mesh
(with roofline accounting) AND the 2-pod mesh (compile proof only). Each cell
runs in a fresh subprocess (crash isolation, clean XLA state); completed cells
are skipped on re-run (JSON cache).

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import cells, list_archs


def cell_done(out: str, arch: str, shape: str, mp: bool) -> bool:
    path = os.path.join(out, f"{arch}__{shape}__{'mp' if mp else 'sp'}.json")
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return json.load(f).get("status") == "ok"
    except Exception:
        return False


def run_one(out: str, arch: str, shape: str, mp: bool, timeout: int) -> str:
    if cell_done(out, arch, shape, mp):
        return "cached"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if mp:
        cmd += ["--multi-pod", "--skip-accounting"]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=timeout)
        status = "ok" if proc.returncode == 0 else "error"
        if status == "error":
            tail = (proc.stderr or proc.stdout or "")[-1500:]
            path = os.path.join(
                out, f"{arch}__{shape}__{'mp' if mp else 'sp'}.json")
            if not os.path.exists(path):
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                               "status": "error", "error": "subprocess",
                               "traceback": tail}, f, indent=1)
    except subprocess.TimeoutExpired:
        status = "timeout"
        path = os.path.join(out, f"{arch}__{shape}__{'mp' if mp else 'sp'}.json")
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "error", "error": f"timeout {timeout}s"}, f)
    return f"{status} ({time.time()-t0:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--only-arch", default=None)
    ap.add_argument("--skip-multipod", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = [args.only_arch] if args.only_arch else list_archs()
    todo = []
    for arch in archs:
        for shape in cells(arch):
            todo.append((arch, shape, False))
    if not args.skip_multipod:
        for arch in archs:
            for shape in cells(arch):
                todo.append((arch, shape, True))

    for i, (arch, shape, mp) in enumerate(todo):
        tag = f"[{i+1}/{len(todo)}] {arch} {shape} {'2-pod' if mp else '1-pod'}"
        print(tag, "...", flush=True)
        print(tag, "->", run_one(args.out, arch, shape, mp, args.timeout),
              flush=True)


if __name__ == "__main__":
    main()
