"""Dry-run sweep driver: every (arch x shape) cell on the single-pod mesh
(with roofline accounting) AND the 2-pod mesh (compile proof only). Each cell
runs in a fresh subprocess (crash isolation, clean XLA state); completed cells
are skipped on re-run (JSON cache). Before launching cells, the deployment-
plan cache is warmed across the union of every arch's GEMM workload (shapes
deduped across archs — the whole point of a shared plan store); the
persisted plans under --plan-cache are a sweep artifact alongside the
dry-run JSONs, reusable by any later Planner on the same hw fingerprint.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import cells, get_config, list_archs


def warm_plans(archs, cache_dir: str, grid, max_candidates: int) -> None:
    """Batch-tune the bucketed union of all archs' GEMM shapes."""
    from repro.deploy import arch_workload
    from repro.deploy.warmup import build_planner, warm_buckets

    workload = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in cells(arch):
            workload += arch_workload(cfg, shape_name)
    warm_buckets(build_planner(cache_dir, grid, max_candidates), workload)


def cell_done(out: str, arch: str, shape: str, mp: bool) -> bool:
    path = os.path.join(out, f"{arch}__{shape}__{'mp' if mp else 'sp'}.json")
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return json.load(f).get("status") == "ok"
    except Exception:
        return False


def run_one(out: str, arch: str, shape: str, mp: bool, timeout: int,
            plan_cache: str = "", plan_grid=(4, 4)) -> str:
    if cell_done(out, arch, shape, mp):
        return "cached"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if plan_cache:
        # cells record their traced GEMM workload against the warmed cache
        # and report model_workload coverage in their JSON
        cmd += ["--plan-cache", plan_cache,
                "--plan-grid", str(plan_grid[0]), str(plan_grid[1])]
    if mp:
        cmd += ["--multi-pod", "--skip-accounting"]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=timeout)
        status = "ok" if proc.returncode == 0 else "error"
        if status == "error":
            tail = (proc.stderr or proc.stdout or "")[-1500:]
            path = os.path.join(
                out, f"{arch}__{shape}__{'mp' if mp else 'sp'}.json")
            if not os.path.exists(path):
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                               "status": "error", "error": "subprocess",
                               "traceback": tail}, f, indent=1)
    except subprocess.TimeoutExpired:
        status = "timeout"
        path = os.path.join(out, f"{arch}__{shape}__{'mp' if mp else 'sp'}.json")
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "error", "error": f"timeout {timeout}s"}, f)
    return f"{status} ({time.time()-t0:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--only-arch", default=None)
    ap.add_argument("--skip-multipod", action="store_true")
    from repro.deploy.warmup import add_plan_args
    add_plan_args(ap)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = [args.only_arch] if args.only_arch else list_archs()
    plan_cache = ""
    if not args.skip_plan_warmup:
        warm_plans(archs, args.plan_cache, args.plan_grid,
                   args.plan_candidates)
        # cells (subprocesses) get the warmed cache via --plan-cache: each
        # installs a record-only gemm context and reports workload coverage
        plan_cache = args.plan_cache
    todo = []
    for arch in archs:
        for shape in cells(arch):
            todo.append((arch, shape, False))
    if not args.skip_multipod:
        for arch in archs:
            for shape in cells(arch):
                todo.append((arch, shape, True))

    for i, (arch, shape, mp) in enumerate(todo):
        tag = f"[{i+1}/{len(todo)}] {arch} {shape} {'2-pod' if mp else '1-pod'}"
        print(tag, "...", flush=True)
        print(tag, "->", run_one(args.out, arch, shape, mp, args.timeout,
                                 plan_cache=plan_cache,
                                 plan_grid=args.plan_grid),
              flush=True)


if __name__ == "__main__":
    main()
