"""Serving launcher: batched prefill + decode loop against preallocated
KV caches. At startup the deployment-plan cache is warmed for the model's
GEMM workload (bucketed + exact shapes) and the decode-path schedules are
reported; repeated launches resolve plans from the persisted store instead
of re-tuning. The warmed planner is then installed as the model stack's
`GemmContext`, so every `pmm` matmul dispatches through
`dit_gemm(plan=...)` — the tuned dataflow, not a hardcoded mode, decides
each GEMM's collective pattern. At shutdown the launcher reports the
planner hit rate over the matmuls the model actually traced and
cross-validates `model_workload`'s prediction against them
(docs/architecture.md walks the full path).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.deploy import Planner, model_workload, workload_coverage
from repro.deploy.batcher import BATCH_MODES, BatchPolicy
from repro.deploy.warmup import add_plan_args, build_planner, warm_buckets
from repro.launch.mesh import make_host_mesh
from repro.models import shard_ctx
from repro.models.matmul import pmm
from repro.models.model import decode_init, decode_step, forward, init_params
from repro.obs import (DriftMonitor, Tracer, build_run_report,
                       render_run_report, set_tracer, write_run_report)
from repro.obs.trace import CAT_STEP, maybe_span
from repro.train.steps import make_serve_step


def warm_plan_cache(cfg, batch: int, prompt_len: int, max_len: int,
                    cache_dir: str, grid, max_candidates: int,
                    online_tune: bool = True) -> Planner:
    """Batch-tune the model's (bucketed) GEMM workload into the plan cache.

    Warms BOTH the batched-prefill shapes (M = batch*prompt_len; a real
    deployment prefills in one pass, and the persisted cache is its
    artifact) and the decode shapes (M = batch) this launcher's
    token-by-token loop actually executes."""
    planner = build_planner(cache_dir, grid, max_candidates,
                            online_tune=online_tune)
    decode = model_workload(cfg, batch, max_len, kind="decode")
    workload = model_workload(cfg, batch, prompt_len, kind="prefill") + decode
    warm_buckets(planner, workload)
    plans = {shape: planner.plan(shape)          # exact shapes: warm hits or
             for shape in dict.fromkeys(workload)}   # cheap transfers
    # the decode path dominates serving; report its planned schedules
    for shape in list(dict.fromkeys(decode))[:4]:
        plan = plans[shape]
        print(f"  decode {shape.m}x{shape.n}x{shape.k}: "
              f"{plan.schedule.describe()} "
              f"est={plan.report.total_time*1e6:.2f}us [{plan.source}]")
    return planner


def install_gemm_context(planner: Planner) -> shard_ctx.GemmContext:
    """Route the model stack's matmuls through the warmed planner: install
    the gemm context `models.matmul.pmm` consults at trace time."""
    ctx = shard_ctx.GemmContext(mesh=make_host_mesh(), planner=planner)
    shard_ctx.set_gemm_context(ctx)
    return ctx


def load_drift(plan_cache: str, plan_grid) -> dict:
    """Drift of the persisted calibration profile vs its persisted
    measurement samples (both written by `dryrun --calibrate` next to the
    plans), or None when the cache dir carries no calibration."""
    from repro.hw.config import tpu_pod_as_accelerator
    from repro.sim import calibrate as cal
    hw = tpu_pod_as_accelerator(tuple(plan_grid))
    profile = cal.load_profile(plan_cache, hw)
    samples = cal.load_samples(plan_cache, hw)
    if profile is None or not samples:
        return None
    mon = DriftMonitor(profile)
    mon.add_samples(samples)
    return mon.summary()


def build_serve_report(ctx: shard_ctx.GemmContext, cfg, batch: int,
                       max_len: int, plan_cache: str = "",
                       plan_grid=(4, 4), tracer=None) -> dict:
    """The versioned run report: routing stats + model_workload
    cross-validation + calibration drift + per-dispatch provenance.

    The coverage prediction is the decode workload only: this launcher
    prefills token-by-token through the cache, so every executed step is a
    decode-shaped trace (M = batch). The batched-prefill shapes warmed at
    startup are a cache artifact for real deployments, not something this
    loop runs — comparing against them would report phantom gaps."""
    stats = ctx.stats
    predicted = model_workload(cfg, batch, max_len, kind="decode")
    cov = workload_coverage(predicted, stats.observed_shapes())
    workload = {
        "observed": len(stats.observed_shapes()),
        "predicted": len(predicted),
        "covered": cov["covered"],
        "extra": [[s.m, s.n, s.k] for s in cov["extra"]],
        "missing": [[s.m, s.n, s.k] for s in cov["missing"]],
    }
    drift = load_drift(plan_cache, plan_grid) if plan_cache else None
    return build_run_report("serve", stats=stats.to_dict(),
                            workload=workload, drift=drift, tracer=tracer,
                            extra={"arch": cfg.name, "batch": batch,
                                   "max_len": max_len})


def report_routing(ctx: shard_ctx.GemmContext, cfg, batch: int,
                   max_len: int) -> None:
    """Shutdown print, rendered from the same dict the run report writes."""
    for line in render_run_report(build_serve_report(ctx, cfg, batch,
                                                     max_len)):
        print(line)


def run_traffic(args) -> None:
    """`--traffic` mode: replay a seeded multi-tenant trace through the
    continuous batcher against the warmed planner (docs/serving.md).

    The virtual-clock loop in `launch/traffic.py` does the SLO accounting;
    every distinct GEMM shape the replay admits is executed ONCE through
    the real routed `pmm` path on the mesh (trace-time semantics — shapes
    are static under jit, so one execution per shape is the honest unit of
    dispatch work). The run report gains a `serving` section and the
    tracer gains one marker per completed request.
    """
    from repro.launch.traffic import (TenantSpec, TrafficConfig,
                                      generate_trace, serving_section,
                                      simulate, warm_pool)
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    policy = BatchPolicy(mode=args.batch_mode)
    tenants = tuple(
        TenantSpec(name=f"tenant{i}", arch=cfg.name,
                   rate_rps=args.traffic_rate,
                   n_requests=args.traffic_requests,
                   prompt_lens=(5, 9, 13, 17), gen_lens=(2, 3, 5))
        for i in range(args.traffic_tenants))
    tcfg = TrafficConfig(seed=args.traffic_seed, tenants=tenants)
    cfgs = {t.name: cfg for t in tenants}

    planner = build_planner(args.plan_cache, args.plan_grid,
                            args.plan_candidates,
                            online_tune=not args.no_online_tune)
    warmed = warm_pool(planner, {cfg.name: cfg}, policy,
                       tcfg.max_rows(policy))
    print(f"traffic: warmed {len(warmed)} pool shape(s) "
          f"[mode={policy.mode}]")
    gemm_ctx = install_gemm_context(planner)
    tracer = Tracer(process_name=f"serve.traffic.{cfg.name}")
    set_tracer(tracer)

    def dispatch(shape, phase):
        # one real routed execution per distinct shape the replay admits
        x = jnp.zeros((shape.m, shape.k), cfg.dtype)
        w = jnp.zeros((shape.k, shape.n), cfg.dtype)
        run = jax.jit(lambda a, b: pmm(a, b, tag=f"traffic.{phase}"))
        np.asarray(run(x, w))

    trace = generate_trace(tcfg)
    t0 = time.time()
    result = simulate(trace, planner, cfgs, policy=policy,
                      precompiled=warmed, dispatch=dispatch)
    wall = time.time() - t0
    section = serving_section(result)
    for rec in result.records:
        tracer.instant("serve.request", cat=CAT_STEP, rid=rec.rid,
                       tenant=rec.tenant,
                       arrival_s=round(rec.arrival_s, 6),
                       ttft_s=round(rec.ttft_s, 6),
                       latency_s=round(rec.latency_s, 6), met=rec.met)
    print(f"traffic replay: {len(trace)} requests / "
          f"{len(tenants)} tenant(s), {section['batches']} batches, "
          f"{section['distinct_shapes']} distinct GEMM shape(s) "
          f"dispatched in {wall:.2f}s wall "
          f"({section['makespan_s']:.3f}s virtual)")
    report = build_run_report(
        "serve", stats=gemm_ctx.stats.to_dict(), tracer=tracer,
        extra={"arch": cfg.name, "serving": section,
               "traffic": {"seed": tcfg.seed, "tenants": len(tenants),
                           "requests": len(trace),
                           "rate_rps": args.traffic_rate,
                           "batch_mode": policy.mode}})
    for line in render_run_report(report):
        print(line)
    if args.run_report:
        write_run_report(args.run_report, report)
        print(f"run report: {args.run_report}")
    if args.trace:
        tracer.write(args.trace)
        print(f"chrome trace: {args.trace}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--no-plan-routing", action="store_true",
                    help="warm the cache but keep matmuls un-routed")
    ap.add_argument("--cold-serve", action="store_true",
                    help="skip the workload warm-up entirely: every traced "
                         "GEMM resolves through the planner's online "
                         "(analytic) tuning path — the real-time-planner "
                         "proof, asserted in CI from the run report")
    ap.add_argument("--refine-pending", type=int, default=0, metavar="N",
                    help="after serving, full-tune up to N bucket/analytic-"
                         "served shapes and upgrade their cache entries")
    ap.add_argument("--traffic", action="store_true",
                    help="replay a seeded multi-tenant traffic trace "
                         "through the shape-bucket-aware continuous "
                         "batcher instead of the fixed-batch loop "
                         "(docs/serving.md)")
    ap.add_argument("--traffic-requests", type=int, default=12,
                    help="requests per tenant in the replayed trace")
    ap.add_argument("--traffic-rate", type=float, default=100.0,
                    help="per-tenant Poisson arrival rate (req/s)")
    ap.add_argument("--traffic-seed", type=int, default=0,
                    help="trace seed (same seed -> identical trace)")
    ap.add_argument("--traffic-tenants", type=int, default=2,
                    help="concurrent tenants sharing the mesh + plan cache")
    ap.add_argument("--batch-mode", choices=BATCH_MODES, default="bucket",
                    help="admission policy: bucket-aware (default) or the "
                         "naive-FIFO baseline")
    ap.add_argument("--run-report", default="results/serve_run_report.json",
                    help="where to write the versioned run report "
                         "('' disables)")
    ap.add_argument("--trace", default="",
                    help="write a Perfetto-loadable Chrome trace here")
    add_plan_args(ap)
    args = ap.parse_args()

    if args.traffic:
        run_traffic(args)
        return

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(1)

    max_len = args.prompt_len + args.gen
    gemm_ctx = None
    planner = None
    tracer = None
    if not args.skip_plan_warmup:
        if args.cold_serve:
            # no warming: the planner starts empty (or with whatever the
            # cache dir already holds) and cold shapes online-tune from the
            # analytic shortlist at trace time
            planner = build_planner(args.plan_cache, args.plan_grid,
                                    args.plan_candidates,
                                    online_tune=not args.no_online_tune)
        else:
            planner = warm_plan_cache(cfg, args.batch, args.prompt_len,
                                      max_len, args.plan_cache,
                                      args.plan_grid, args.plan_candidates,
                                      online_tune=not args.no_online_tune)
        if not args.no_plan_routing:
            gemm_ctx = install_gemm_context(planner)
            tracer = Tracer(process_name=f"serve.{cfg.name}")
            set_tracer(tracer)
    caches = decode_init(params, cfg, args.batch, max_len)
    serve = jax.jit(make_serve_step(cfg))

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    enc_kwargs = {}
    if cfg.is_encoder_decoder:
        enc = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_prefix, cfg.d_model)) * 0.02, cfg.dtype)
        enc_kwargs["encoder_out"] = enc @ params["frontend_proj"]

    # prefill token-by-token through the cache (keeps one compiled step)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        with maybe_span("serve.prefill_token", position=i):
            logits, caches = serve(params, caches, prompts[:, i:i + 1],
                                   jnp.asarray(i), **enc_kwargs)
    t_prefill = time.time() - t0

    generated = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        generated.append(np.asarray(tok)[:, 0])
        with maybe_span("serve.decode_token", position=i):
            logits, caches = serve(params, caches, tok,
                                   jnp.asarray(args.prompt_len + i),
                                   **enc_kwargs)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_gen = time.time() - t0

    gen = np.stack(generated, 1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill:.2f}s | "
          f"decode {args.gen} tok in {t_gen:.2f}s "
          f"({args.batch * args.gen / max(t_gen, 1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[:2]:
        print(" ", row[:16].tolist())
    if planner is not None and args.refine_pending \
            and planner.pending_refinements:
        recs = planner.refine_pending(limit=args.refine_pending)
        print(f"refinement: full-tuned {len(recs)} online/bucket-served "
              f"shape(s); "
              f"{sum(1 for _, old, new in recs if new < old)} improved "
              f"(every refined entry is now tuned-provenance)")
    if gemm_ctx is not None:
        report = build_serve_report(gemm_ctx, cfg, args.batch, max_len,
                                    plan_cache=args.plan_cache,
                                    plan_grid=args.plan_grid, tracer=tracer)
        for line in render_run_report(report):
            print(line)
        if args.run_report:
            write_run_report(args.run_report, report)
            print(f"run report: {args.run_report}")
        if args.trace and tracer is not None:
            tracer.write(args.trace)
            print(f"chrome trace: {args.trace}")


if __name__ == "__main__":
    main()
