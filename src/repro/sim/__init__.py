from repro.sim.calibrate import (CalibrationProfile, CalibrationSample,
                                 calibrate_mesh, fit_profile, is_trusted,
                                 load_profile, measure_modes, rank_stats,
                                 ranking_cost, save_profile)
from repro.sim.perf import PerfReport, estimate
from repro.sim.softhier import FunctionalSim, SimResult, run_gemm, verify_gemm

__all__ = ["CalibrationProfile", "CalibrationSample", "PerfReport",
           "calibrate_mesh", "estimate", "fit_profile", "is_trusted",
           "load_profile", "measure_modes", "rank_stats", "ranking_cost",
           "save_profile", "FunctionalSim", "SimResult",
           "run_gemm", "verify_gemm"]
