from repro.sim.perf import PerfReport, estimate
from repro.sim.softhier import FunctionalSim, SimResult, run_gemm, verify_gemm

__all__ = ["PerfReport", "estimate", "FunctionalSim", "SimResult",
           "run_gemm", "verify_gemm"]
