"""SoftHier executable model — functional BSP executor (paper §2.1, §2.3).

Executes a BSP `Program` over a tile grid with real data: per-tile L1 buffers
(numpy arrays, one per declared slot), HBM held as whole matrices (the
channel-level preload/packing path is exercised separately by
`repro.core.layout.pack_preload`). The executor implements strict BSP
semantics: within a superstep, MMADs read the L1 state left by previous
barriers; communication issued in a superstep becomes visible at its barrier.

This is the 'functional evaluation' half of SoftHier; the performance half
(cycle estimation with HBM-channel and NoC contention) is `repro.sim.perf`.
Numerics run in float32 shadow precision regardless of the deployment dtype
declared on the buffers (the declared dtype sizes the L1-capacity check and
the byte counts in the cost model).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.ir import DMAOp, MMADOp, MulticastOp, P2POp, Program, ReduceOp


@dataclasses.dataclass
class SimResult:
    c: np.ndarray
    supersteps: int
    op_counts: Dict[str, int]


class FunctionalSim:
    """Functional execution of one GEMM program: C = A @ B."""

    def __init__(self, prog: Program, a: np.ndarray, b: np.ndarray):
        self.prog = prog
        m, n, k = prog.shape
        if a.shape != (m, k) or b.shape != (k, n):
            raise ValueError(f"operand shapes {a.shape} {b.shape} do not match "
                             f"program GEMM {prog.shape}")
        self.a = a.astype(np.float32)
        self.b = b.astype(np.float32)
        self.c = np.zeros((m, n), dtype=np.float32)
        self.tm, self.tn, self.tk = prog.tile_shape
        # l1[tile][buf] = list of per-slot arrays (lazily allocated)
        self.l1: Dict[Tuple[int, int], Dict[str, list]] = {}

    # -- L1 access -----------------------------------------------------------

    def _buf(self, tile, name, slot) -> Optional[np.ndarray]:
        return self.l1.get(tile, {}).get(name, {}).get(slot)

    def _set(self, tile, name, slot, value: np.ndarray) -> None:
        decl = self.prog.buffers[name]
        if not (0 <= slot < decl.slots):
            raise IndexError(f"slot {slot} out of range for buffer {name!r} "
                             f"({decl.slots} slots)")
        self.l1.setdefault(tile, {}).setdefault(name, {})[slot] = value

    # -- HBM tile access -------------------------------------------------------

    def _hbm_read(self, matrix: str, tile_coord) -> np.ndarray:
        ti, tj = tile_coord
        if matrix == "A":
            return self.a[ti * self.tm:(ti + 1) * self.tm,
                          tj * self.tk:(tj + 1) * self.tk].copy()
        if matrix == "B":
            return self.b[ti * self.tk:(ti + 1) * self.tk,
                          tj * self.tn:(tj + 1) * self.tn].copy()
        return self.c[ti * self.tm:(ti + 1) * self.tm,
                      tj * self.tn:(tj + 1) * self.tn].copy()

    def _hbm_write(self, matrix: str, tile_coord, value, accumulate: bool) -> None:
        if matrix != "C":
            raise ValueError("only C may be stored")
        ti, tj = tile_coord
        view = self.c[ti * self.tm:(ti + 1) * self.tm,
                      tj * self.tn:(tj + 1) * self.tn]
        if accumulate:
            view += value
        else:
            view[...] = value

    # -- execution -------------------------------------------------------------

    def run(self) -> SimResult:
        for step in self.prog.supersteps:
            # compute phase reads pre-barrier state
            for op in step.compute:
                a = self._buf(op.tile, op.a_buf, op.a_slot)
                b = self._buf(op.tile, op.b_buf, op.b_slot)
                if a is None or b is None:
                    raise RuntimeError(
                        f"MMAD on {op.tile} reads empty buffer "
                        f"{op.a_buf}[{op.a_slot}]/{op.b_buf}[{op.b_slot}] "
                        f"in superstep {step.label!r}")
                acc = self._buf(op.tile, op.acc_buf, op.acc_slot)
                prod = a @ b
                if op.init or acc is None:
                    self._set(op.tile, op.acc_buf, op.acc_slot, prod)
                else:
                    acc += prod
            # communication. DMA loads apply first (fabric multicasts may
            # chain off an owner's same-superstep DMA, `after_dma`); NoC ops
            # then read post-DMA state; all other effects land at the barrier.
            for op in step.comm:
                if isinstance(op, DMAOp) and op.kind == "load":
                    self._set(op.tile, op.buf, op.slot,
                              self._hbm_read(op.matrix, op.tile_coord))
            effects = []
            for op in step.comm:
                if isinstance(op, DMAOp):
                    if op.kind == "load":
                        pass  # applied above
                    else:
                        src = self._buf(op.tile, op.buf, op.slot)
                        if src is None:
                            raise RuntimeError(f"store from empty buffer on {op.tile} "
                                               f"({op.buf}[{op.slot}])")
                        effects.append(("hbm", op.matrix, op.tile_coord,
                                        src.copy(), op.accumulate))
                elif isinstance(op, MulticastOp):
                    src = self._buf(op.src, op.buf, op.slot)
                    if src is None:
                        raise RuntimeError(f"multicast from empty buffer on {op.src} "
                                           f"({op.buf}[{op.slot}]) step {step.label!r}")
                    dst_buf = op.dst_buf or op.buf
                    dst_slot = op.slot if op.dst_slot is None else op.dst_slot
                    for member in op.group.members(self.prog.grid):
                        effects.append(("set", member, dst_buf, dst_slot, src.copy()))
                elif isinstance(op, ReduceOp):
                    total = None
                    for member in op.group.members(self.prog.grid):
                        v = self._buf(member, op.buf, op.slot)
                        if v is None:
                            raise RuntimeError(f"reduce reads empty buffer on {member}")
                        total = v.copy() if total is None else total + v
                    dst_buf = op.dst_buf or op.buf
                    effects.append(("set", op.dst, dst_buf, op.slot, total))
                elif isinstance(op, P2POp):
                    src = self._buf(op.src, op.buf, op.slot)
                    if src is None:
                        raise RuntimeError(f"p2p from empty buffer on {op.src} "
                                           f"({op.buf}[{op.slot}]) step {step.label!r}")
                    dst_slot = op.slot if op.dst_slot is None else op.dst_slot
                    dst_buf = op.dst_buf or op.buf
                    effects.append(("set", op.dst, dst_buf, dst_slot, src.copy()))
                else:
                    raise TypeError(f"unknown comm op {type(op)}")
            # barrier: apply effects
            for eff in effects:
                if eff[0] == "set":
                    _, tile, buf, slot, value = eff
                    self._set(tile, buf, slot, value)
                else:
                    _, matrix, coord, value, acc = eff
                    self._hbm_write(matrix, coord, value, acc)
        return SimResult(self.c, len(self.prog.supersteps), self.prog.op_counts())


def run_gemm(prog: Program, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Convenience: execute the program and return C."""
    return FunctionalSim(prog, a, b).run().c


def verify_gemm(prog: Program, a: np.ndarray, b: np.ndarray,
                rtol: float = 1e-4, atol: float = 1e-4) -> None:
    """The paper's 'compare results against reference outputs' workflow stage."""
    c = run_gemm(prog, a, b)
    ref = a.astype(np.float32) @ b.astype(np.float32)
    np.testing.assert_allclose(c, ref, rtol=rtol, atol=atol)
