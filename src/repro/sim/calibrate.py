"""Measured calibration of the SoftHier cost model (the paper's "connecting a
deployment toolchain with a configurable executable model", closed as a loop).

The analytical model in `sim/perf.py` prices a schedule from hardware
constants alone — a *prior*. This module fits that prior to the machine it is
actually deployed on, TVM/Ansor-style (PAPERS.md), with a deliberately tiny
learned layer: one scale factor per resource class.

Model: a report attributes its predicted `total_time` to compute / DMA / NoC
via `PerfReport.resource_shares()`; the fitted predictor is

    measured ~= a * (total * share_c) + b * (total * share_d)
              + c * (total * share_n) + h * n_supersteps

so identity factors (a = b = c = 1, h = 0) reproduce the analytical
prediction exactly, and least squares over (prediction, measurement) pairs
absorbs the global units gap (simulated accelerator seconds vs wall seconds
on the local mesh), the per-resource mispricing that flips schedule
rankings, and the per-superstep launch/sync overhead that dominates on
hosts whose fabric is emulated.

Trust is explicit: `fit_profile` only sets `fit_ok` when the fit explains the
measurements (R^2 over threshold), does not *worsen* rank agreement on its
own fit set, and its picks' measured time is no worse than the uncalibrated
picks'. Downstream (autotuner / Planner) uses the calibrated ranking — and
widens the DEFAULT search space to the hierarchical compositions — only for
trusted profiles; an untrusted profile degrades to the analytical prior.

`measure_modes` is the measurement harness: every executable mode (the
shared `MODE_CASES` table below — `benchmarks/routing_bench.py`'s
efficiency harness consumes the same table and `time_best_of` discipline,
so the two can't drift) runs the same GEMM grid on the local mesh, lowering
asserted clean before timing, yielding the (PerfReport, measured seconds)
pairs the fit consumes. Profiles persist next to the plan cache keyed by
hardware fingerprint (`save_profile` / `load_profile`), so a warmed
deployment directory carries its calibration.

Everything except `measure_modes` is jax-free (the fit must run device-free
in tests and on machines that only replay persisted measurements).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math
import os
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.schedule import GEMMShape, Schedule, Tiling
from repro.hw.config import AcceleratorConfig
from repro.sim.perf import PerfReport

PROFILE_SCHEMA_VERSION = 1

# fit-trust gates (see fit_profile): explain the data, don't hurt the
# rankings you were fitted to fix. The R^2 floor is deliberately mild — the
# sharp gates are the rank ones (agreement must not drop, and the calibrated
# picks' measured time must not exceed the analytical picks') because
# ranking is what the tuner consumes.
FIT_R2_THRESHOLD = 0.5
FIT_MIN_SAMPLES = 6


# ---------------------------------------------------------------------------
# The measurement record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibrationSample:
    """One (schedule prediction, measured execution) pair."""
    shape: Tuple[int, int, int]        # (M, N, K)
    dataflow: str                      # Schedule.dataflow
    mode: str                          # ExecPlan mode it lowered to
    report: PerfReport                 # analytical prediction
    measured_s: float                  # wall seconds on the local mesh

    def to_dict(self) -> Dict[str, object]:
        return {"shape": list(self.shape), "dataflow": self.dataflow,
                "mode": self.mode, "report": self.report.to_dict(),
                "measured_s": self.measured_s}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "CalibrationSample":
        return cls(shape=tuple(d["shape"]), dataflow=d["dataflow"],
                   mode=d["mode"], report=PerfReport.from_dict(d["report"]),
                   measured_s=d["measured_s"])


def _features(report: PerfReport) -> Tuple[float, float, float, float]:
    """The fit's X row: per-resource attribution of the predicted total,
    plus the superstep count (per-step launch/sync overhead is the term
    that dominates on hosts where the fabric is emulated — its identity
    coefficient is 0, so the prior is reproducible exactly)."""
    sc, sd, sn = report.resource_shares()
    t = report.total_time
    return (t * sc, t * sd, t * sn, float(report.n_supersteps))


# ---------------------------------------------------------------------------
# The fitted artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Per-resource scale factors fitted to measured mode efficiency.

    `fit_ok` is the trust bit: only a trusted profile changes autotuner
    behaviour (calibrated ranking + hierarchical compositions in the DEFAULT
    search space). An identity profile with `fit_ok=False` is the explicit
    "no usable calibration" value — it predicts exactly the analytical prior.
    """
    hw_name: str
    hw_digest: str
    compute_scale: float = 1.0
    dma_scale: float = 1.0
    noc_scale: float = 1.0
    # fitted seconds of launch/sync overhead per superstep (0 = none; the
    # dominant term on hosts where the fabric is emulated)
    step_overhead_s: float = 0.0
    # fit-quality record
    n_samples: int = 0
    r2: float = 0.0
    geomean_ratio: float = 1.0          # geomean(measured / calibrated pred)
    rank_agreement_before: float = 0.0  # analytical argmin == measured argmin
    rank_agreement_after: float = 0.0   # calibrated argmin == measured argmin
    picks_measured_ratio: float = 1.0   # geomean measured(calibrated picks)
                                        #       / measured(analytical picks)
    fit_ok: bool = False
    schema_version: int = PROFILE_SCHEMA_VERSION

    def digest(self) -> str:
        """Stable id of this profile (recorded on calibrated plans/reports)."""
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:12]

    def predict(self, report: PerfReport) -> float:
        """Calibrated total-time prediction for an analytical report."""
        fc, fd, fn, steps = _features(report)
        return (self.compute_scale * fc + self.dma_scale * fd
                + self.noc_scale * fn + self.step_overhead_s * steps)

    @classmethod
    def identity(cls, hw: AcceleratorConfig, n_samples: int = 0,
                 fit_ok: bool = False) -> "CalibrationProfile":
        from repro.deploy.plan import hw_fingerprint
        return cls(hw_name=hw.name, hw_digest=hw_fingerprint(hw),
                   n_samples=n_samples, fit_ok=fit_ok)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "CalibrationProfile":
        version = d.get("schema_version")
        if version != PROFILE_SCHEMA_VERSION:
            raise ValueError(f"calibration schema version {version!r} not "
                             f"supported (reader is at "
                             f"{PROFILE_SCHEMA_VERSION})")
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        return cls.from_dict(json.loads(text))

    def describe(self) -> str:
        return (f"calibration[{self.hw_name} n={self.n_samples} "
                f"scales=({self.compute_scale:.3g},{self.dma_scale:.3g},"
                f"{self.noc_scale:.3g}) step={self.step_overhead_s:.3g}s "
                f"r2={self.r2:.3f} "
                f"{'trusted' if self.fit_ok else 'UNTRUSTED'}]")


# ---------------------------------------------------------------------------
# Least-squares fit
# ---------------------------------------------------------------------------

N_FEATURES = 4          # compute, dma, noc attributions + superstep count


def _lstsq(rows: List[Tuple[float, ...]],
           y: List[float]) -> Optional[Tuple[float, ...]]:
    """Non-negative least squares over the feature columns, by best-subset
    enumeration (2^N candidate supports — exact, dependency-free, and
    deterministic, which a wobbliness-prone iterative NNLS is not)."""
    import numpy as np
    X = np.asarray(rows, dtype=np.float64)
    yv = np.asarray(y, dtype=np.float64)
    best: Optional[Tuple[float, Tuple[float, ...]]] = None
    for support in itertools.product((0, 1), repeat=N_FEATURES):
        idx = [i for i in range(N_FEATURES) if support[i]]
        if idx:
            sol, *_ = np.linalg.lstsq(X[:, idx], yv, rcond=None)
            if not np.all(np.isfinite(sol)) or np.any(sol < 0.0):
                continue
            coefs = [0.0] * N_FEATURES
            for i, c in zip(idx, sol):
                coefs[i] = float(c)
        else:
            coefs = [0.0] * N_FEATURES
        resid = yv - X @ np.asarray(coefs)
        sse = float(resid @ resid)
        if best is None or sse < best[0] - 1e-18:
            best = (sse, tuple(coefs))
    if best is None or all(c == 0.0 for c in best[1]):
        return None
    return best[1]


def is_trusted(profile) -> bool:
    """THE trust predicate every downstream ranker shares: only a profile
    that passed fit_profile's gates may change tuner behaviour."""
    return profile is not None and getattr(profile, "fit_ok", False)


def ranking_cost(profile):
    """The cost function a tuner ranks candidates by under `profile`:
    the calibrated prediction when trusted, else the analytical prior."""
    if is_trusted(profile):
        return profile.predict
    return lambda report: report.total_time


def rank_stats(samples: Sequence[CalibrationSample],
               cost_fn) -> Tuple[float, float, int]:
    """(rank agreement with the measured argmin, geomean measured time of
    the cost_fn picks, number of groups) across shapes that measured more
    than one mode. Shared by fit_profile's trust gate and
    benchmarks/calibration_bench.py — the CI bar `calibrated picks measure
    no worse` is exactly the gate's own statistic, so the two cannot
    drift."""
    by_shape: Dict[Tuple[int, int, int], List[CalibrationSample]] = {}
    for s in samples:
        by_shape.setdefault(s.shape, []).append(s)
    agree, groups, log_sum = 0, 0, 0.0
    for group in by_shape.values():
        if len(group) < 2:
            continue
        groups += 1
        pick = min(group, key=lambda s: cost_fn(s.report))
        measured_best = min(group, key=lambda s: s.measured_s)
        if pick.mode == measured_best.mode:
            agree += 1
        log_sum += math.log(max(pick.measured_s, 1e-30))
    if not groups:
        return 1.0, 1.0, 0
    return agree / groups, math.exp(log_sum / groups), groups


def fit_profile(samples: Sequence[CalibrationSample], hw: AcceleratorConfig,
                r2_threshold: float = FIT_R2_THRESHOLD,
                min_samples: int = FIT_MIN_SAMPLES) -> CalibrationProfile:
    """Least-squares per-resource scale factors from measured samples.

    Degenerate inputs (too few samples, non-positive measurements,
    rank-deficient features, zero variance) fall back to the identity
    profile with `fit_ok=False` — never a half-fitted profile.
    """
    import numpy as np
    from repro.deploy.plan import hw_fingerprint

    clean = [s for s in samples
             if s.measured_s > 0.0 and s.report.total_time > 0.0]
    if len(clean) < max(3, min_samples):
        return CalibrationProfile.identity(hw, n_samples=len(clean))
    rows = [_features(s.report) for s in clean]
    y = [s.measured_s for s in clean]
    # genuine rank deficiency is handled inside _lstsq: a support whose
    # columns cannot fit returns non-finite/negative solutions and is
    # skipped, and an all-zero best support yields None -> identity below
    coefs = _lstsq(rows, y)
    if coefs is None:
        return CalibrationProfile.identity(hw, n_samples=len(clean))
    a, b, c, h = coefs

    yv = np.asarray(y)
    pred = np.asarray(rows) @ np.asarray(coefs)
    sse = float(np.sum((yv - pred) ** 2))
    sst = float(np.sum((yv - yv.mean()) ** 2))
    if sst <= 0.0:                      # all measurements identical
        return CalibrationProfile.identity(hw, n_samples=len(clean))
    r2 = 1.0 - sse / sst
    ratios = np.log(np.maximum(yv, 1e-30) / np.maximum(pred, 1e-30))
    geomean_ratio = float(np.exp(ratios.mean()))

    profile = CalibrationProfile(
        hw_name=hw.name, hw_digest=hw_fingerprint(hw),
        compute_scale=a, dma_scale=b, noc_scale=c, step_overhead_s=h,
        n_samples=len(clean), r2=r2, geomean_ratio=geomean_ratio)
    before, before_pick_t, _ = rank_stats(clean, lambda r: r.total_time)
    after, after_pick_t, _ = rank_stats(clean, profile.predict)
    picks_ratio = (after_pick_t / before_pick_t if before_pick_t > 0.0
                   else 1.0)
    fit_ok = (r2 >= r2_threshold and after >= before
              and picks_ratio <= 1.0 + 1e-9)
    return dataclasses.replace(profile,
                               rank_agreement_before=before,
                               rank_agreement_after=after,
                               picks_measured_ratio=picks_ratio,
                               fit_ok=fit_ok)


# ---------------------------------------------------------------------------
# Persistence (alongside the plan cache, keyed by hardware fingerprint)
# ---------------------------------------------------------------------------

def _profile_path(cache_dir: str, hw_digest: str) -> str:
    return os.path.join(cache_dir, f"calibration_{hw_digest}.profile.json")


def save_profile(cache_dir: str, profile: CalibrationProfile) -> str:
    """Persist a profile next to the plans it calibrates (atomic publish)."""
    os.makedirs(cache_dir, exist_ok=True)
    path = _profile_path(cache_dir, profile.hw_digest)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(profile.to_json())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def _samples_path(cache_dir: str, hw_digest: str) -> str:
    return os.path.join(cache_dir, f"calibration_{hw_digest}.samples.json")


def save_samples(cache_dir: str, hw_digest: str,
                 samples: Sequence[CalibrationSample]) -> str:
    """Persist the measurements a profile was fitted from, next to it.

    The persisted samples let a later run compute predicted-vs-measured
    drift (`repro.obs.drift.DriftMonitor`) against the persisted profile
    WITHOUT re-running the measurement harness — serve/dryrun report drift
    from the calibration run's ground truth."""
    os.makedirs(cache_dir, exist_ok=True)
    path = _samples_path(cache_dir, hw_digest)
    doc = {"schema_version": PROFILE_SCHEMA_VERSION, "hw_digest": hw_digest,
           "samples": [s.to_dict() for s in samples]}
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def load_samples(cache_dir: str,
                 hw: AcceleratorConfig) -> List[CalibrationSample]:
    """The persisted samples for `hw`, or [] (missing / corrupt /
    incompatible schema / fingerprint mismatch are all misses)."""
    from repro.deploy.plan import hw_fingerprint
    digest = hw_fingerprint(hw)
    path = _samples_path(cache_dir, digest)
    try:
        with open(path) as f:
            doc = json.load(f)
        if (doc.get("schema_version") != PROFILE_SCHEMA_VERSION
                or doc.get("hw_digest") != digest):
            return []
        return [CalibrationSample.from_dict(d) for d in doc["samples"]]
    except (OSError, ValueError, KeyError, TypeError):
        return []


def load_profile(cache_dir: str,
                 hw: AcceleratorConfig) -> Optional[CalibrationProfile]:
    """The persisted profile for `hw`, or None (missing / corrupt /
    incompatible schema / fingerprint mismatch are all misses)."""
    from repro.deploy.plan import hw_fingerprint
    digest = hw_fingerprint(hw)
    path = _profile_path(cache_dir, digest)
    try:
        with open(path) as f:
            profile = CalibrationProfile.from_json(f.read())
    except (OSError, ValueError, KeyError, TypeError,
            json.JSONDecodeError):
        return None
    if profile.hw_digest != digest:
        return None
    return profile


# ---------------------------------------------------------------------------
# The measurement harness (jax; reuses routing_bench's per-mode machinery)
# ---------------------------------------------------------------------------

# label -> (schedule dataflow, tiling/owner knobs); THE table of executable
# modes — `measure_modes` below and benchmarks/routing_bench.py's
# efficiency harness both consume it, so a new mode lands in the
# calibration fit and the efficiency matrix together or not at all. Each
# case must lower to exactly its label on a square mesh >= 4x4 (asserted
# before timing).
MODE_CASES: Tuple[Tuple[str, str, Dict[str, object]], ...] = (
    ("summa", "summa", {}),
    ("cannon", "systolic", {}),
    ("splitk_summa", "splitk_summa", {"gk": 2, "owner": "round_robin"}),
    ("hierarchical", "summa_over_systolic", {}),
    ("outer_systolic", "systolic_over_summa", {}),
)

DEFAULT_GEMM_GRID: Tuple[Tuple[int, int, int], ...] = (
    (256, 256, 512), (512, 256, 1024), (512, 512, 512), (256, 512, 2048),
)


def build_mode_schedule(dataflow: str, knobs: Dict[str, object],
                        rows: int, cols: int,
                        shape: Tuple[int, int, int],
                        elem_bytes: int = 1,
                        inner_kernel=None, overlap: bool = False) -> Schedule:
    """The Schedule for one MODE_CASES row on a rows x cols grid.

    The k sub-axis factors out of the column axis (gm * gn * gk covers the
    grid exactly), so the same schedule both prices with the analytical
    model on an `AcceleratorConfig` of that grid AND lowers to exactly its
    labelled mode on the matching mesh. `inner_kernel`/`overlap` pass
    through to the schedule so the kernel benchmark can measure the same
    mode with and without the intra-device level engaged.
    """
    gk = int(knobs.get("gk", 1))
    return Schedule(GEMMShape(*shape), Tiling(rows, cols // gk, gk, tk=64),
                    dataflow, reduce_owner=str(knobs.get("owner", "first")),
                    inner=(2, 2), elem_bytes=elem_bytes,
                    inner_kernel=inner_kernel, overlap=overlap)


def time_best_of(fn, a, b, reps: int) -> float:
    """Best-of-`reps` wall seconds, 3 executions per rep, after one
    compile+warm call (the shared timing discipline of the measurement
    harness and the routing benchmark)."""
    import jax
    jax.block_until_ready(fn(a, b))          # compile + warm
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(a, b)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / 3)
    return best


def measure_modes(hw: AcceleratorConfig, mesh=None,
                  gemms: Sequence[Tuple[int, int, int]] = DEFAULT_GEMM_GRID,
                  reps: int = 2,
                  row_axis: str = "data", col_axis: str = "model",
                  ) -> List[CalibrationSample]:
    """Execute every mode over a GEMM shape grid on the local mesh.

    For each (GEMM, mode): the schedule is priced with the analytical model
    on `hw`, its lowering onto `mesh` is asserted clean (a silent degrade
    would pair `auto`'s measurement with another mode's prediction), and the
    execution is timed best-of-`reps`, 3 calls per rep after a compile+warm
    call. `hw.grid` must match the mesh so prediction and measurement
    describe the same machine.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.gemm import dit_gemm
    from repro.core.lower import lower_schedule
    from repro.core.schedule import build_program
    from repro.sim.perf import estimate

    if mesh is None:
        mesh = jax.make_mesh(hw.grid, (row_axis, col_axis))
    rows, cols = (mesh.shape[row_axis], mesh.shape[col_axis])
    if (rows, cols) != tuple(hw.grid):
        raise ValueError(f"mesh {rows}x{cols} does not match hw.grid "
                         f"{hw.grid}; the profile would pair predictions "
                         f"and measurements from different machines")

    rng = np.random.default_rng(0)
    samples: List[CalibrationSample] = []
    for (M, N, K) in gemms:
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        for label, df, kw in MODE_CASES:
            sched = build_mode_schedule(df, kw, rows, cols, (M, N, K),
                                        elem_bytes=hw.tile.elem_bytes)
            ep = lower_schedule(sched, mesh, row_axis, col_axis,
                                shape=(M, N, K))
            if ep.mode != label or ep.degraded:
                raise RuntimeError(f"{df} lowered to {ep.describe()}, "
                                   f"expected clean {label}")
            report = estimate(build_program(sched, hw), hw)
            t = time_best_of(jax.jit(
                lambda x, y, s=sched: dit_gemm(x, y, mesh, plan=s,
                                               row_axis=row_axis,
                                               col_axis=col_axis)), a, b,
                reps)
            samples.append(CalibrationSample(
                shape=(M, N, K), dataflow=df, mode=label,
                report=report, measured_s=t))
    return samples


def calibrate_mesh(hw: AcceleratorConfig, mesh=None,
                   gemms: Sequence[Tuple[int, int, int]] = DEFAULT_GEMM_GRID,
                   reps: int = 2,
                   ) -> Tuple[CalibrationProfile, List[CalibrationSample]]:
    """measure_modes + fit_profile in one call (the dryrun/bench entry)."""
    samples = measure_modes(hw, mesh, gemms=gemms, reps=reps)
    return fit_profile(samples, hw), samples
