"""SoftHier performance model (paper §2.1 'cycle-accurate analysis').

Prices a BSP `Program` on an `AcceleratorConfig`. Per superstep the three
resource classes are priced independently and combined with BSP max semantics
(compute, DMA and NoC phases overlap inside a superstep by construction —
builders emit serialized supersteps when a schedule disables double
buffering):

- **compute**: per-tile matrix-engine time. The engine is a ce_rows x ce_cols
  MAC array; an MMAD over (TM x TN x TK) issues ceil(TM/ce_rows) *
  ceil(TN/ce_cols) output chunks, each pipelined over TK with a
  (ce_rows + ce_cols)-cycle fill — this reproduces the paper's observation
  that TN = 66 tiles reach only ~50% engine utilization while TN = 528 tiles
  are efficient (§4.1.3). L1 feed bandwidth is a secondary bound.
- **DMA**: HBM-channel contention. Each DMA's bytes land on the channel given
  by the matrix's DataLayout; a superstep's DMA time is the busiest channel's
  bytes / channel_bw (channels operate in parallel — exactly why the paper's
  optimized split scheme helps) plus the busiest tile's L1 port time.
- **NoC**: collectives are priced on a dimension-ordered multicast/reduce tree
  (vertical distribution on the source column + horizontal distribution along
  each spanned row); every spanned link resource accumulates bytes and the
  busiest resource bounds the phase. P2P ops charge the links on their
  dimension-ordered route. Hardware collectives traverse links once —
  the mask-based broadcast of §2.1.

The model is an analytical prior (no RTL); all constants come from
`AcceleratorConfig`. `sim/calibrate.py` fits measured per-resource scale
factors on top of it — `PerfReport.calibrated(profile)` rescales a report by
a fitted `CalibrationProfile`, turning the prior into a per-hardware
predictor the autotuner can trust.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from repro.core.ir import (ELEM_BYTES_OF_DTYPE, DMAOp, MMADOp, MulticastOp,
                           P2POp, Program, ReduceOp)
from repro.core.masks import TileGroup
from repro.core.schedule import InnerKernel
from repro.hw.config import AcceleratorConfig


@functools.lru_cache(maxsize=16384)
def _members(group: TileGroup, grid: Tuple[int, int]) -> Tuple[Tuple[int, int], ...]:
    return tuple(group.members(grid))


@dataclasses.dataclass
class PerfReport:
    total_time: float
    compute_time: float
    dma_time: float
    noc_time: float
    barrier_time: float
    total_flops: int
    hbm_bytes: int
    noc_bytes: int
    n_supersteps: int
    # digest of the CalibrationProfile whose measured scale factors rescaled
    # this report ("" = the raw analytical prior; see sim/calibrate.py).
    calibration: str = ""

    @property
    def achieved_flops(self) -> float:
        return self.total_flops / self.total_time if self.total_time else 0.0

    def utilization(self, hw: AcceleratorConfig) -> float:
        return self.achieved_flops / hw.peak_flops

    @property
    def intensity(self) -> float:
        return self.total_flops / self.hbm_bytes if self.hbm_bytes else math.inf

    def bw_utilization(self, hw: AcceleratorConfig) -> float:
        return (self.hbm_bytes / self.total_time) / hw.hbm.total_bw if self.total_time else 0.0

    def to_dict(self) -> Dict[str, float]:
        """Flat JSON-able form (stable field set — part of the plan schema)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "PerfReport":
        return cls(**d)

    def resource_shares(self) -> Tuple[float, float, float]:
        """(compute, dma, noc) fractions of the busy time — how this report
        attributes its total to the three resource classes. The calibration
        layer's feature vector is `total_time * shares` (so identity scale
        factors reproduce `total_time` exactly)."""
        busy = self.compute_time + self.dma_time + self.noc_time
        if busy <= 0.0:
            return (1.0, 0.0, 0.0)
        return (self.compute_time / busy, self.dma_time / busy,
                self.noc_time / busy)

    def calibrated(self, profile) -> "PerfReport":
        """This report rescaled by a fitted `CalibrationProfile`.

        Each resource component is multiplied by its measured scale factor
        and `total_time` becomes the profile's prediction (clamped so the
        superstep invariant total >= max(component, barrier) survives any
        scale combination). An identity profile returns an identical report
        apart from the recorded calibration digest.
        """
        c = self.compute_time * profile.compute_scale
        d = self.dma_time * profile.dma_scale
        n = self.noc_time * profile.noc_scale
        total = max(profile.predict(self), c, d, n, self.barrier_time)
        return dataclasses.replace(self, total_time=total, compute_time=c,
                                   dma_time=d, noc_time=n,
                                   calibration=profile.digest())

    def summary(self, hw: AcceleratorConfig) -> str:
        return (f"time={self.total_time*1e6:.1f}us "
                f"TFLOPS={self.achieved_flops/1e12:.1f} "
                f"util={self.utilization(hw)*100:.1f}% "
                f"AI={self.intensity:.1f} "
                f"bw={self.bw_utilization(hw)*100:.1f}% "
                f"steps={self.n_supersteps}")


def _engine_time(op: MMADOp, hw: AcceleratorConfig,
                 inner: Optional[InnerKernel] = None) -> float:
    """Per-tile matrix-engine time for one MMAD, optionally under a tuned
    `InnerKernel`.

    `inner=None` is the legacy single-level model (XLA/firmware picks the
    intra-tile loop): one pipeline fill per output chunk, operands fed at the
    hardware's native element width.

    With an inner kernel the geometry terms become visible to the planner:

    - **MXU occupancy**: the (tm x tn) tile splits into ceil(tm/bm) *
      ceil(tn/bn) blocks, each issuing ceil(bm/ce_rows) * ceil(bn/ce_cols)
      engine passes — a bm/bn misaligned with the CE array wastes array rows
      exactly as the paper's §4.1.3 TN=66 case does.
    - **accumulator-flush / pipeline-fill amortization vs bk**: each block
      runs ceil(tk/bk) K-chunks; a double-buffered pipeline (depth >= 2)
      pays the (ce_rows + ce_cols)-cycle fill once per engine pass, while a
      serialized pipeline (depth 1) re-fills every K-chunk AND exposes the
      L1 feed time instead of hiding it behind compute.
    - **fp8-aware feed**: operands stream at the *kernel's* element width, so
      a narrower compute dtype relieves a feed-bound tile (the paper's
      1979 TFLOPS@FP8 headline is exactly this term at full scale).

    An aligned kernel (bm | tm with ce_rows | bm, ditto bn, bk == tk,
    depth >= 2, dtype at the native width) prices EXACTLY like the legacy
    model — candidate sweeps tie instead of fabricating a difference.
    """
    t = hw.tile
    fill = t.ce_rows + t.ce_cols
    if inner is None:
        chunks = math.ceil(op.tm / t.ce_rows) * math.ceil(op.tn / t.ce_cols)
        cycles = chunks * (op.tk + fill)
        engine = cycles / t.clock_hz
        feed_bytes = (op.tm * op.tk + op.tk * op.tn) * t.elem_bytes
        return max(engine, feed_bytes / t.l1_bw)

    blocks = math.ceil(op.tm / inner.bm) * math.ceil(op.tn / inner.bn)
    sub = (math.ceil(min(inner.bm, op.tm) / t.ce_rows)
           * math.ceil(min(inner.bn, op.tn) / t.ce_cols))
    kchunks = math.ceil(op.tk / inner.bk)
    fills = fill if inner.depth >= 2 else kchunks * fill
    cycles = blocks * sub * (kchunks * inner.bk + fills)
    engine = cycles / t.clock_hz
    eb = ELEM_BYTES_OF_DTYPE.get(inner.dtype, t.elem_bytes)
    feed = (op.tm * op.tk + op.tk * op.tn) * eb / t.l1_bw
    return max(engine, feed) if inner.depth >= 2 else engine + feed


# -- two-phase estimation ----------------------------------------------------
# Communication pricing (DMA channel contention, NoC link trees, barrier) is
# independent of the inner kernel; only the compute phase changes. The sweep
# over inner-kernel candidates in `price_candidates` therefore runs the
# expensive comm pass ONCE per program and recombines per kernel.

@dataclasses.dataclass
class _StepProfile:
    comp: List[Tuple[Tuple[int, int], Tuple[int, int, int]]]  # (tile, dims)
    d_time: float
    n_time: float
    chained: bool


@dataclasses.dataclass
class _CommProfile:
    steps: List[_StepProfile]
    barrier: float
    flops: int
    hbm_bytes: int
    noc_bytes: int


def _comm_profile(prog: Program, hw: AcceleratorConfig) -> _CommProfile:
    grid = prog.grid
    barrier = (grid[0] + grid[1]) * hw.noc.hop_latency_cycles / hw.tile.clock_hz

    flops = 0
    hbm_bytes = 0
    noc_bytes = 0
    steps: List[_StepProfile] = []

    buf_bytes = {}
    for name, decl in prog.buffers.items():
        eb = ELEM_BYTES_OF_DTYPE.get(decl.dtype)
        if eb is None:
            raise KeyError(f"buffer {name!r} has unpriceable dtype "
                           f"{decl.dtype!r}; add it to ELEM_BYTES_OF_DTYPE")
        buf_bytes[name] = decl.shape[0] * decl.shape[1] * eb

    for step in prog.supersteps:
        # -- compute phase: record op dims, priced later per inner kernel
        comp: List[Tuple[Tuple[int, int], Tuple[int, int, int]]] = []
        for op in step.compute:
            comp.append((op.tile, (op.tm, op.tn, op.tk)))
            flops += 2 * op.tm * op.tn * op.tk

        # -- DMA phase: channel + L1-port contention
        chan_bytes: Dict[int, int] = {}
        tile_bytes: Dict[Tuple[int, int], int] = {}
        # -- NoC phase: link-resource contention
        row_res: Dict[int, int] = {}
        col_res: Dict[int, int] = {}
        link_res: Dict[Tuple[Tuple[int, int], Tuple[int, int]], int] = {}
        local_res: Dict[Tuple[int, int], int] = {}
        max_hop_lat = 0.0

        for op in step.comm:
            if isinstance(op, DMAOp):
                if op.matrix == "C":
                    # C commits at the deployment element size (the L1
                    # accumulator stays fp32, so buf_bytes would overcount).
                    tm, tn, _ = prog.tile_shape
                    nbytes = tm * tn * prog.elem_bytes
                else:
                    nbytes = buf_bytes[op.buf]
                layout = prog.layouts[op.matrix]
                mshape = _matrix_shape(prog, op.matrix)
                ch = layout.channel_of_tile(*op.tile_coord, mshape)
                chan_bytes[ch] = chan_bytes.get(ch, 0) + nbytes
                tile_bytes[op.tile] = tile_bytes.get(op.tile, 0) + nbytes
                hbm_bytes += nbytes
            elif isinstance(op, (MulticastOp, ReduceOp)):
                nbytes = buf_bytes[op.buf]
                anchor = op.src if isinstance(op, MulticastOp) else op.dst
                members = _members(op.group, grid)
                rows = sorted({i for i, _ in members})
                cols = sorted({j for _, j in members})
                # dimension-ordered tree: vertical leg on the anchor column,
                # horizontal leg along each spanned row.
                if len(rows) > 1:
                    col_res[anchor[1]] = col_res.get(anchor[1], 0) + nbytes
                if len(cols) > 1:
                    for r in rows:
                        row_res[r] = row_res.get(r, 0) + nbytes
                hops = (rows[-1] - rows[0]) + (cols[-1] - cols[0])
                max_hop_lat = max(max_hop_lat,
                                  hops * hw.noc.hop_latency_cycles / hw.tile.clock_hz)
                noc_bytes += nbytes * max(1, len(members) - 1)
            elif isinstance(op, P2POp):
                nbytes = buf_bytes[op.buf]
                if op.src == op.dst:
                    local_res[op.src] = local_res.get(op.src, 0) + nbytes
                    continue
                # dimension-ordered route: along the row, then the column
                (si, sj), (di, dj) = op.src, op.dst
                for j in range(min(sj, dj), max(sj, dj)):
                    link_res[((si, j), (si, j + 1))] = \
                        link_res.get(((si, j), (si, j + 1)), 0) + nbytes
                for i in range(min(si, di), max(si, di)):
                    link_res[((i, dj), (i + 1, dj))] = \
                        link_res.get(((i, dj), (i + 1, dj)), 0) + nbytes
                hops = abs(si - di) + abs(sj - dj)
                max_hop_lat = max(max_hop_lat,
                                  hops * hw.noc.hop_latency_cycles / hw.tile.clock_hz)
                noc_bytes += nbytes
            else:
                raise TypeError(f"unknown comm op {type(op)}")

        d_time = 0.0
        if chan_bytes:
            d_time = max(b / hw.hbm.channel_bw for b in chan_bytes.values())
        if tile_bytes:
            d_time = max(d_time, max(b / hw.tile.l1_bw for b in tile_bytes.values()))
        n_time = 0.0
        for res in (row_res, col_res):
            if res:
                n_time = max(n_time, max(b / hw.noc.link_bw for b in res.values()))
        if link_res:
            n_time = max(n_time, max(b / hw.noc.link_bw for b in link_res.values()))
        if local_res:
            n_time = max(n_time, max(b / hw.tile.l1_bw for b in local_res.values()))
        n_time += max_hop_lat

        # a multicast chained off a same-superstep owner DMA serializes the
        # DMA and NoC phases (fetch -> fabric multicast dependency).
        chained = any(isinstance(op, MulticastOp) and op.after_dma for op in step.comm)
        steps.append(_StepProfile(comp=comp, d_time=d_time, n_time=n_time,
                                  chained=chained))

    return _CommProfile(steps=steps, barrier=barrier, flops=flops,
                        hbm_bytes=hbm_bytes, noc_bytes=noc_bytes)


def _combine(prog: Program, hw: AcceleratorConfig, profile: _CommProfile,
             inner: Optional[InnerKernel]) -> PerfReport:
    """Recombine a comm profile with the compute phase under one inner
    kernel. With `inner=None` this reproduces the single-pass estimate
    bit-identically (same op order, same float additions)."""
    tot = comp_t = dma_t = noc_t = 0.0
    etime: Dict[Tuple[int, int, int], float] = {}
    for step in profile.steps:
        per_tile: Dict[Tuple[int, int], float] = {}
        for tile, dims in step.comp:
            e = etime.get(dims)
            if e is None:
                tm, tn, tk = dims
                e = etime[dims] = _engine_time(
                    MMADOp(tile=tile, a_buf="A", a_slot=0, b_buf="B",
                           b_slot=0, tm=tm, tn=tn, tk=tk), hw, inner)
            per_tile[tile] = per_tile.get(tile, 0.0) + e
        c_time = max(per_tile.values(), default=0.0)
        comm_time = (step.d_time + step.n_time if step.chained
                     else max(step.d_time, step.n_time))
        tot += max(c_time, comm_time) + profile.barrier
        comp_t += c_time
        dma_t += step.d_time
        noc_t += step.n_time

    return PerfReport(total_time=tot, compute_time=comp_t, dma_time=dma_t,
                      noc_time=noc_t,
                      barrier_time=profile.barrier * len(profile.steps),
                      total_flops=profile.flops,
                      hbm_bytes=profile.hbm_bytes,
                      noc_bytes=profile.noc_bytes,
                      n_supersteps=len(profile.steps))


def estimate(prog: Program, hw: AcceleratorConfig,
             inner: Optional[InnerKernel] = None) -> PerfReport:
    return _combine(prog, hw, _comm_profile(prog, hw), inner)


def estimate_sweep(prog: Program, hw: AcceleratorConfig,
                   inners: Iterable[Optional[InnerKernel]]
                   ) -> Iterator[Tuple[Optional[InnerKernel], PerfReport]]:
    """Price one program under several inner kernels, running the expensive
    communication pass once. Yields (inner, report) in the given order —
    callers that keep the first strict minimum therefore inherit the
    sweep's tie-break ordering."""
    profile = _comm_profile(prog, hw)
    for inner in inners:
        yield inner, _combine(prog, hw, profile, inner)


def _matrix_shape(prog: Program, matrix: str) -> Tuple[int, int]:
    m, n, k = prog.shape
    return {"A": (m, k), "B": (k, n), "C": (m, n)}[matrix]


# -- fused attention (FlatAttention) ------------------------------------------

def _attn_gemm_time(tm: int, tn: int, tk: int, hw: AcceleratorConfig) -> float:
    """Engine cycles for one (tm x tn x tk) contraction, legacy model (the
    fused dataflow has no inner kernel — softmax sits between the two
    contractions, so the Pallas mmad pipeline does not apply)."""
    t = hw.tile
    fill = t.ce_rows + t.ce_cols
    chunks = math.ceil(tm / t.ce_rows) * math.ceil(tn / t.ce_cols)
    return chunks * (tk + fill)


def estimate_attention(sched, hw: AcceleratorConfig,
                       head_shard: Optional[bool] = None) -> PerfReport:
    """Price an `AttnSchedule` on `hw` with the same BSP superstep semantics
    as `estimate`: per superstep the busiest resource bounds the phase, plus
    a grid barrier; components accumulate so `resource_shares` (and hence
    `CalibrationProfile.predict`) rescales attention exactly like GEMMs.

    One superstep streams one `kv_chunk`-wide KV tile through L1: QKᵀ
    (sq_l x chunk x d), ~4 vector passes over the logits for the online
    softmax (max, exp, row-sum, rescale), then PV (sq_l x dv x chunk).

    - **merge**: KV row-sharded; every device scans its local KV, then ONE
      combine superstep pmax/psum-reduces the (m, l, acc) partials over the
      row axis.
    - **ring**: Q additionally row-sharded; the local KV shard rotates
      around a ppermute ring, so each device runs dm passes and each step's
      NoC phase carries the KV block to the next neighbour.

    The caller guarantees lowering legality (skv % dm == 0; ring also
    sq % dm == 0) — `attn_candidates` only emits legal schedules and
    `lower_attention` re-checks at dispatch.
    """
    shp = sched.shape
    dm, dn = hw.grid
    eb = sched.elem_bytes
    if head_shard is None:
        head_shard = (dn > 1 and shp.h % dn == 0
                      and (shp.hkv % dn == 0 or shp.hkv == 1))
    h_l = shp.h // dn if head_shard else shp.h
    hkv_l = shp.hkv // dn if (head_shard and shp.hkv % dn == 0) else shp.hkv
    ring = sched.composition == "ring" and dm > 1
    kv_l = max(1, shp.skv // max(1, dm))
    sq_l = max(1, shp.sq // dm) if ring else shp.sq
    chunk = max(1, min(sched.kv_chunk, kv_l))
    steps_per_pass = math.ceil(kv_l / chunk)
    passes = dm if ring else 1
    n_steps = steps_per_pass * passes

    t = hw.tile
    # compute phase: both contractions + the softmax's vector passes, per
    # (batch, local head), one KV chunk per superstep
    cycles = (_attn_gemm_time(sq_l, chunk, shp.d, hw)
              + _attn_gemm_time(sq_l, shp.dv, chunk, hw)
              + 4 * sq_l * chunk)
    engine = shp.b * h_l * cycles / t.clock_hz
    feed = shp.b * (h_l * sq_l * shp.d + hkv_l * chunk * (shp.d + shp.dv)) * eb
    comp_step = max(engine, feed / t.l1_bw)

    # DMA phase: Q in + O out once, the local KV shard streamed once per
    # pass; balanced channel layout, so the busiest channel carries the
    # per-device share (global bytes / total HBM bandwidth)
    q_bytes = shp.b * shp.sq * shp.h * shp.d * eb
    o_bytes = shp.b * shp.sq * shp.h * shp.dv * eb
    kv_bytes = shp.b * shp.skv * shp.hkv * (shp.d + shp.dv) * eb
    hbm_bytes = q_bytes + o_bytes + kv_bytes * passes
    dma_total = hbm_bytes / hw.hbm.total_bw
    dma_step = dma_total / n_steps

    hop = hw.noc.hop_latency_cycles / t.clock_hz
    barrier = (dm + dn) * hop

    if ring:
        # each step also rotates the KV shard one hop around the ring
        block = shp.b * kv_l * hkv_l * (shp.d + shp.dv) * eb
        noc_step = block / hw.noc.link_bw + hop
        noc_bytes = block * max(0, dm - 1)
        total = n_steps * (max(comp_step, dma_step, noc_step) + barrier)
        noc_time = noc_step * n_steps
        n_supersteps = n_steps
    else:
        # scan supersteps, then one combine superstep reducing the fp32
        # (m, l, acc) partials over the dm-member row tree
        partial = shp.b * h_l * sq_l * (2 + shp.dv) * 4
        noc_time = partial / hw.noc.link_bw + max(0, dm - 1) * hop
        noc_bytes = partial * max(0, dm - 1)
        total = (n_steps * (max(comp_step, dma_step) + barrier)
                 + (noc_time + barrier if dm > 1 else 0.0))
        n_supersteps = n_steps + (1 if dm > 1 else 0)
        if dm <= 1:
            noc_time = 0.0

    compute_time = comp_step * n_steps
    report = PerfReport(total_time=max(total, compute_time, dma_total,
                                       noc_time, barrier),
                        compute_time=compute_time, dma_time=dma_total,
                        noc_time=noc_time,
                        barrier_time=barrier * n_supersteps,
                        total_flops=shp.flops(), hbm_bytes=hbm_bytes,
                        noc_bytes=noc_bytes, n_supersteps=n_supersteps)
    return report
