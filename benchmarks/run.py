"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Figures 7/9/10/11/12 run the DiT
schedules through the SoftHier cost model on the paper's hardware instances;
microbench covers the host-executable pieces. The roofline benchmark reads
the dry-run artifacts if present (results/dryrun). `routing_bench` also
writes the BENCH_routing.json artifact (plan-resolve latency, per-mode
trace+lower cost, per-mode execution efficiency vs XLA auto) and
`calibration_bench` writes BENCH_calibration.json (cost-model fit quality,
rank agreement, calibrated-vs-analytical pick quality), `tracing_bench`
writes BENCH_tracing.json (observability-layer overhead on the dispatch
path, with asserted bounds), `analytic_bench` writes BENCH_analytic.json
(closed-form shortlist rank agreement vs exhaustive search, with asserted
bounds) and `kernel_bench` writes BENCH_kernel.json (the inner-kernel
schedule level: local_matmul vs jnp.dot, routed kernel-on/off, ring
overlap on/off, tune-vs-analytic inner-pick agreement, with asserted
bounds) and `serving_bench` writes BENCH_serving.json (SLO serving under
replayed multi-tenant traffic: bucket-aware vs naive-FIFO admission
goodput/p99/resolve-rate, with asserted bounds) and `attention_bench`
writes BENCH_attention.json (the FlatAttention fused dataflow: planner
resolution + clean lowering per shape, predicted fused-vs-unfused
geomean, fake-mesh wall time, with asserted bounds) — every BENCH_*
artifact's schema, production command, and regression meaning is
documented in docs/benchmarking.md."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (analytic_bench, attention_bench,
                            calibration_bench, fig7_case_study,
                            fig9_11_gh200, fig12_portability, kernel_bench,
                            microbench, plan_bench, routing_bench,
                            serving_bench, tracing_bench)
    modules = [
        ("fig7", fig7_case_study),
        ("fig9-11", fig9_11_gh200),
        ("fig12", fig12_portability),
        ("micro", microbench),
        ("plan", plan_bench),
        ("routing", routing_bench),
        ("calibration", calibration_bench),
        ("tracing", tracing_bench),
        ("analytic", analytic_bench),
        ("kernel", kernel_bench),
        ("serving", serving_bench),
        ("attention", attention_bench),
    ]
    try:
        from benchmarks import roofline_table
        modules.append(("roofline", roofline_table))
    except ImportError:
        pass
    for name, mod in modules:
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:
            print(f"{name}.ERROR,0.0,{type(e).__name__}:{str(e)[:120]}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
