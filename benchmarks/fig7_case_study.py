"""Paper Fig. 7 (a-d): the GEMM case-study plots, reproduced on the SoftHier
GH200-class instance via the DiT cost model.

7a — layout + dataflow roofline movement (baseline/SUMMA x base/optimal layout)
7b — dataflow pattern comparison across shape regimes
7c — 2-D SUMMA vs 3-D split-K on the irregular-N shape
7d — cluster-dimension remap on the flat GEMM
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

from benchmarks.common import csv_row
from repro.core.layout import base_layout
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.hw.config import softhier_gh200
from repro.sim.perf import estimate

HW = softhier_gh200()
SHAPE_IRREG = GEMMShape(4096, 2112, 7168)       # paper's compute-intensive case
SHAPE_FLAT = GEMMShape(64, 2112, 7168)          # paper's flat/decode case
SHAPE_STORE = GEMMShape(16384, 32768, 512)      # paper's store-intensive case


def _run(sched: Schedule):
    t0 = time.perf_counter()
    prog = build_program(sched, HW)
    rep = estimate(prog, HW)
    return rep, (time.perf_counter() - t0) * 1e6


def fig7a() -> List[str]:
    rows = []
    base_lay = {m: base_layout(s, 128, 128, HW.hbm.n_channels)
                for m, s in (("A", (4096, 7168)), ("B", (7168, 2112)),
                             ("C", (4096, 2112)))}
    cases = [
        ("baseline_w/o_layout", Schedule(SHAPE_IRREG, Tiling(32, 32, 1, tk=128),
                                         "baseline", elem_bytes=1,
                                         layouts=base_lay)),
        ("baseline_w_layout", Schedule(SHAPE_IRREG, Tiling(32, 32, 1, tk=128),
                                       "baseline", elem_bytes=1)),
        ("summa_w/o_layout", Schedule(SHAPE_IRREG, Tiling(32, 32, 1, tk=128),
                                      "summa", elem_bytes=1, layouts=base_lay)),
        ("summa_w_layout", Schedule(SHAPE_IRREG, Tiling(32, 32, 1, tk=128),
                                    "summa", elem_bytes=1)),
    ]
    for name, sched in cases:
        rep, us = _run(sched)
        rows.append(csv_row(
            f"fig7a.{name}", us,
            f"AI={rep.intensity:.0f};TFLOPS={rep.achieved_flops/1e12:.0f};"
            f"util={rep.utilization(HW)*100:.1f}%"))
    return rows


def fig7b() -> List[str]:
    rows = []
    for regime, shape, tk in (("compute", SHAPE_IRREG, 128),
                              ("store", SHAPE_STORE, 128)):
        iters = (1, 1) if regime == "compute" else (4, 8)
        for df, stages in (("summa", 1), ("summa", 4), ("systolic", 1),
                           ("systolic_over_summa", 1), ("summa_over_systolic", 1)):
            t = Tiling(32, 32, 1, iter_m=iters[0], iter_n=iters[1], tk=tk)
            try:
                rep, us = _run(Schedule(shape, t, df, elem_bytes=1,
                                        store_stages=stages))
                rows.append(csv_row(
                    f"fig7b.{regime}.{df}.st{stages}", us,
                    f"TFLOPS={rep.achieved_flops/1e12:.0f};"
                    f"util={rep.utilization(HW)*100:.1f}%"))
            except ValueError as e:
                rows.append(csv_row(f"fig7b.{regime}.{df}.st{stages}", 0.0,
                                    f"illegal:{str(e)[:40]}"))
    return rows


def fig7c() -> List[str]:
    rows = []
    cases = [
        ("2d_summa_tn66", Schedule(SHAPE_IRREG, Tiling(32, 32, 1, tk=128),
                                   "summa", elem_bytes=1)),
        ("3d_splitk_tn264", Schedule(SHAPE_IRREG, Tiling(32, 8, 4, tk=256),
                                     "splitk_summa", elem_bytes=1)),
        ("3d_splitk_tn528", Schedule(SHAPE_IRREG, Tiling(32, 4, 8, tk=128),
                                     "splitk_summa", elem_bytes=1, acc_bytes=2)),
    ]
    for name, sched in cases:
        rep, us = _run(sched)
        rows.append(csv_row(
            f"fig7c.{name}", us,
            f"TFLOPS={rep.achieved_flops/1e12:.0f};"
            f"util={rep.utilization(HW)*100:.1f}%"))
    return rows


def fig7d() -> List[str]:
    rows = []
    cases = [
        ("2d_summa_32x32", Schedule(SHAPE_FLAT, Tiling(32, 32, 1, tk=224),
                                    "summa", elem_bytes=1)),
        ("remap_3d_1x4x256", Schedule(SHAPE_FLAT, Tiling(1, 4, 256, tk=28),
                                      "splitk_summa", elem_bytes=1)),
    ]
    reps = []
    for name, sched in cases:
        rep, us = _run(sched)
        reps.append(rep)
        rows.append(csv_row(
            f"fig7d.{name}", us,
            f"TFLOPS={rep.achieved_flops/1e12:.1f};"
            f"bw_util={rep.bw_utilization(HW)*100:.1f}%"))
    speedup = reps[0].total_time / reps[1].total_time
    rows.append(csv_row("fig7d.remap_speedup", 0.0, f"x{speedup:.2f}"))
    return rows


def run() -> List[str]:
    return fig7a() + fig7b() + fig7c() + fig7d()
