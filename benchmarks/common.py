"""Shared benchmark helpers: the paper's benchmark GEMM shapes (DeepGEMM /
DeepSeek-V3 projection shapes, §4.1.4), external reference performance
constants, and timing utilities.

REFERENCE NUMBERS: no GH200/A100 exists in this container, so the comparison
columns use the paper's own published claims and public library data:
- the paper states DiT reaches 1.2-1.5x GH200 TFLOPS on compute-bound shapes
  and 1.2-2.0x on flat shapes (§4.1.4, Figs. 9-11);
- DeepGEMM's public README reports up to ~1358 TFLOPS fp8 on H800 (68.7% of
  1979 peak) for its best large shapes, with 40-60% on irregular/flat ones;
- Fig. 1 of the paper shows CUTLASS 3.9 utilization on GH200 below A100's on
  identical shapes (~45-60% vs ~60-75%).
These are encoded as the GH200_REF / A100_REF tables below and are clearly
labeled as external references in the output.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

from repro.core.schedule import GEMMShape

# DeepSeek-V3 projection GEMMs as benchmarked by DeepGEMM (N, K); M supplies
# the token dimension (4096 for training/prefill-like, 64/128 for decode).
DEEPSEEK_NK: List[Tuple[int, int]] = [
    (2112, 7168),
    (24576, 1536),
    (32768, 512),
    (7168, 16384),
    (4096, 7168),
    (7168, 2048),
]

COMPUTE_BOUND = [GEMMShape(4096, n, k) for (n, k) in DEEPSEEK_NK]
FLAT = [GEMMShape(64, n, k) for (n, k) in DEEPSEEK_NK] + \
       [GEMMShape(128, n, k) for (n, k) in DEEPSEEK_NK]

# external reference utilizations (fraction of peak) per regime — see module
# docstring for provenance. Keyed loosely by N regularity.
GH200_REF_UTIL_COMPUTE = 0.55     # CUTLASS/DeepGEMM on large-M fp8 GEMM
GH200_REF_UTIL_FLAT_BW = 0.60     # fraction of HBM bw on flat GEMM
A100_REF_UTIL_COMPUTE = 0.70      # CUTLASS fp16 on A100 (Fig. 1 regime)


def timeit(fn: Callable, *args, reps: int = 3) -> float:
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
