"""Two-level tuning benchmark -> BENCH_kernel.json (the inner-kernel level
of the schedule: planner-resolved local matmuls + ring/compute overlap).

Four sections, three with asserted bounds so CI fails when engaging the
intra-device level stops being free on this host:

- **local_kernel**: `kernels.ops.local_matmul` under a planner-style
  `InnerKernel` vs the bare `jnp.dot` fp32 oracle, per GEMM, single
  device. On CPU the kernel path IS the oracle (docstring contract), so
  the ratio measures pure dispatch overhead. Bound: geomean <= 1.10.
- **routed_modes**: every executable mode (the shared
  `sim.calibrate.MODE_CASES` table) on the 4x4 host mesh, schedule with
  its closed-form inner-kernel candidate vs `inner_kernel=None`. Lowering
  is asserted clean AND the ExecPlan is asserted to actually carry the
  kernel — a silent drop would benchmark the baseline against itself.
  Bound: per-mode kernel-on/kernel-off geomean <= 1.10.
- **overlap**: the ring modes (cannon, hierarchical, outer_systolic) with
  `Schedule.overlap` on vs off — permute-before-consume must be free (the
  collectives leave the critical path; XLA may or may not exploit it on
  fake devices) and numerically identical (asserted allclose). Bound:
  geomean <= 1.10.
- **agreement**: jax-free — exhaustive `tune` vs `analytic_tune` over a
  shape grid on the mini accelerator, comparing the *inner* pick. Bounds:
  inner-pick match rate >= 0.5, shortlist-best cost within 1.05x of the
  exhaustive optimum, and the joint space must actually engage (the
  exhaustive winner carries a kernel for at least one shape).

Like the routing/tracing benches, the host-mesh ratios measure dispatch
and collective-schedule overhead, not real fabric: on a TPU mesh rerun
the same command to see the Pallas block geometry and async-ring effects
the cost model prices.

Standalone (sets its own fake-device count; run before importing jax
elsewhere):

  PYTHONPATH=src python benchmarks/kernel_bench.py --reps 2

Also exposed to benchmarks/run.py via a subprocess `run()` so the device
count does not leak into the other benchmarks' jax runtime.
"""
import argparse
import json
import math
import os
import time
from typing import List

KERNEL_OVER_DOT_BOUND = 1.10      # local_matmul / jnp.dot geomean
ROUTED_KERNEL_BOUND = 1.10        # routed kernel-on / kernel-off geomean
OVERLAP_BOUND = 1.10              # routed overlap-on / overlap-off geomean
INNER_MATCH_FLOOR = 0.5           # tune vs analytic inner-pick agreement
COST_RATIO_BOUND = 1.05           # analytic-best / exhaustive-best cost

LOCAL_GEMMS = ((256, 256, 512), (512, 512, 512), (384, 512, 1024))
ROUTED_GEMMS = ((256, 256, 512), (512, 512, 512))
RING_MODES = ("cannon", "hierarchical", "outer_systolic")

# agreement grid: shapes divisible by the mini 4x4 grid's tilings
AGREEMENT_SHAPES = ((1024, 1024, 2048), (2048, 1024, 1024),
                    (1024, 2048, 4096), (512, 512, 1024))


def _geomean(xs) -> float:
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 1.0


def _mini_hw(grid=(4, 4)):
    from repro.hw.config import AcceleratorConfig, HBMConfig, TileConfig
    return AcceleratorConfig(name="mini", grid=grid,
                             tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                             hbm=HBMConfig(n_channels=8))


def _bench_local(reps: int) -> dict:
    """local_matmul under an InnerKernel vs the bare jnp.dot oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.schedule import InnerKernel
    from repro.kernels.ops import local_matmul, pick_block_shape
    from repro.sim.calibrate import time_best_of

    rng = np.random.default_rng(0)
    rows = []
    for (m, n, k) in LOCAL_GEMMS:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        ik = InnerKernel(*pick_block_shape(m, n, k, 4), dtype="float32")
        dot = jax.jit(lambda x, y: jnp.dot(
            x, y, preferred_element_type=jnp.float32))
        ker = jax.jit(lambda x, y, kk=ik: local_matmul(x, y, kk))
        t_dot = time_best_of(dot, a, b, reps)
        t_ker = time_best_of(ker, a, b, reps)
        rows.append({"gemm": [m, n, k], "kernel": ik.describe(),
                     "dot_us": round(t_dot * 1e6, 1),
                     "kernel_us": round(t_ker * 1e6, 1),
                     "ratio": round(t_ker / t_dot, 3)})
    return {"gemms": rows,
            "geomean_ratio": round(_geomean(r["ratio"] for r in rows), 3)}


def _routed_fn(sched, mesh, expect_kernel: bool):
    """jit'd dit_gemm through the schedule's ExecPlan, lowering asserted
    clean (and the kernel asserted present/absent as labelled)."""
    import jax

    from repro.core.gemm import dit_gemm
    from repro.core.lower import lower_schedule

    ep = lower_schedule(sched, mesh, "data", "model",
                        shape=(sched.shape.m, sched.shape.n, sched.shape.k))
    if ep.degraded:
        raise RuntimeError(f"{sched.dataflow} degraded: {ep.describe()}")
    if (ep.inner_kernel is not None) != expect_kernel:
        raise RuntimeError(f"{sched.dataflow}: inner kernel "
                           f"{'dropped' if expect_kernel else 'appeared'} "
                           f"in lowering ({ep.describe()})")
    return jax.jit(lambda x, y: dit_gemm(x, y, mesh, exec_plan=ep)), ep


def _bench_routed(reps: int) -> dict:
    """Every mode, kernel-on vs kernel-off, on the 4x4 host mesh."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.schedule import inner_kernel_candidates
    from repro.sim.calibrate import (MODE_CASES, build_mode_schedule,
                                     time_best_of)

    hw = _mini_hw()
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    modes = {}
    for label, df, kw in MODE_CASES:
        cases = []
        for (M, N, K) in ROUTED_GEMMS:
            a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
            b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
            off = build_mode_schedule(df, kw, 4, 4, (M, N, K),
                                      elem_bytes=hw.tile.elem_bytes)
            iks = inner_kernel_candidates(off, hw)
            if not iks:
                raise RuntimeError(f"no inner-kernel candidate for {df} "
                                   f"{(M, N, K)} — the joint space is empty")
            on = dataclasses.replace(off, inner_kernel=iks[0])
            fn_off, _ = _routed_fn(off, mesh, expect_kernel=False)
            fn_on, ep_on = _routed_fn(on, mesh, expect_kernel=True)
            t_off = time_best_of(fn_off, a, b, reps)
            t_on = time_best_of(fn_on, a, b, reps)
            cases.append({"gemm": [M, N, K],
                          "kernel": ep_on.inner_kernel.describe(),
                          "off_us": round(t_off * 1e6, 1),
                          "on_us": round(t_on * 1e6, 1),
                          "ratio": round(t_on / t_off, 3)})
        modes[label] = {"gemms": cases,
                        "geomean_ratio": round(
                            _geomean(c["ratio"] for c in cases), 3)}
    return {"modes": modes,
            "geomean_ratio": round(
                _geomean(m["geomean_ratio"] for m in modes.values()), 3)}


def _bench_overlap(reps: int) -> dict:
    """Ring modes with Schedule.overlap on vs off (numerics asserted)."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.sim.calibrate import (MODE_CASES, build_mode_schedule,
                                     time_best_of)

    hw = _mini_hw()
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    modes = {}
    for label, df, kw in MODE_CASES:
        if label not in RING_MODES:
            continue
        cases = []
        for (M, N, K) in ROUTED_GEMMS:
            a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
            b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
            off = build_mode_schedule(df, kw, 4, 4, (M, N, K),
                                      elem_bytes=hw.tile.elem_bytes)
            on = dataclasses.replace(off, overlap=True)
            fn_off, _ = _routed_fn(off, mesh, expect_kernel=False)
            fn_on, ep_on = _routed_fn(on, mesh, expect_kernel=False)
            if not ep_on.overlap:
                raise RuntimeError(f"{df}: overlap dropped in lowering")
            diff = float(jnp.max(jnp.abs(fn_on(a, b) - fn_off(a, b))))
            if diff > 1e-3:
                raise RuntimeError(f"{df}: overlap moved numerics "
                                   f"(max abs diff {diff})")
            t_off = time_best_of(fn_off, a, b, reps)
            t_on = time_best_of(fn_on, a, b, reps)
            cases.append({"gemm": [M, N, K], "max_abs_diff": diff,
                          "off_us": round(t_off * 1e6, 1),
                          "on_us": round(t_on * 1e6, 1),
                          "ratio": round(t_on / t_off, 3)})
        modes[label] = {"gemms": cases,
                        "geomean_ratio": round(
                            _geomean(c["ratio"] for c in cases), 3)}
    return {"modes": modes,
            "geomean_ratio": round(
                _geomean(m["geomean_ratio"] for m in modes.values()), 3)}


def _bench_agreement() -> dict:
    """Exhaustive tune vs analytic_tune: do they pick the same inner
    kernel, and does the shortlist's winner cost stay near the optimum?
    Pure cost-model arithmetic — no jax, no devices."""
    from repro.core.analytic import analytic_tune
    from repro.core.autotuner import tune
    from repro.core.schedule import GEMMShape

    hw = _mini_hw()
    rows, matches, kernel_picks = [], 0, 0
    t0 = time.perf_counter()
    for (M, N, K) in AGREEMENT_SHAPES:
        shape = GEMMShape(M, N, K)
        full = tune(shape, hw, max_candidates=32)
        short = analytic_tune(shape, hw)
        ik_full = (full.schedule.inner_kernel.describe()
                   if full.schedule.inner_kernel else None)
        ik_short = (short.schedule.inner_kernel.describe()
                    if short.schedule.inner_kernel else None)
        match = ik_full == ik_short
        matches += match
        kernel_picks += ik_full is not None
        rows.append({"shape": [M, N, K],
                     "tune_inner": ik_full, "analytic_inner": ik_short,
                     "tune_dataflow": full.schedule.dataflow,
                     "analytic_dataflow": short.schedule.dataflow,
                     "cost_ratio": round(short.report.total_time
                                         / full.report.total_time, 4),
                     "inner_match": match})
    return {"shapes": rows,
            "inner_match_rate": round(matches / len(rows), 3),
            "kernel_pick_rate": round(kernel_picks / len(rows), 3),
            "max_cost_ratio": round(max(r["cost_ratio"] for r in rows), 4),
            "wall_s": round(time.perf_counter() - t0, 2)}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=2,
                    help="timing repetitions (best-of)")
    ap.add_argument("--out", default="BENCH_kernel.json")
    args = ap.parse_args(argv)

    # must precede the first jax import (the lazy in-function imports
    # above); appended rather than set so a pre-existing XLA_FLAGS keeps
    # its settings alongside the fake-device count.
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=16").strip()

    result = {
        "local_kernel": _bench_local(args.reps),
        "routed_modes": _bench_routed(args.reps),
        "overlap": _bench_overlap(args.reps),
        "agreement": _bench_agreement(),
    }
    result["bounds"] = {
        "local_geomean_ratio": KERNEL_OVER_DOT_BOUND,
        "routed_geomean_ratio": ROUTED_KERNEL_BOUND,
        "overlap_geomean_ratio": OVERLAP_BOUND,
        "inner_match_rate": INNER_MATCH_FLOOR,
        "max_cost_ratio": COST_RATIO_BOUND,
    }
    result["within_bounds"] = (
        result["local_kernel"]["geomean_ratio"] <= KERNEL_OVER_DOT_BOUND
        and result["routed_modes"]["geomean_ratio"] <= ROUTED_KERNEL_BOUND
        and result["overlap"]["geomean_ratio"] <= OVERLAP_BOUND
        and result["agreement"]["inner_match_rate"] >= INNER_MATCH_FLOOR
        and result["agreement"]["max_cost_ratio"] <= COST_RATIO_BOUND
        and result["agreement"]["kernel_pick_rate"] > 0)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    print(f"kernel.local,{result['local_kernel']['geomean_ratio']},"
          f"vs_jnp_dot_geomean")
    print(f"kernel.routed,{result['routed_modes']['geomean_ratio']},"
          f"on_over_off_geomean")
    print(f"kernel.overlap,{result['overlap']['geomean_ratio']},"
          f"on_over_off_geomean")
    print(f"kernel.agreement,{result['agreement']['inner_match_rate']},"
          f"cost_ratio_max={result['agreement']['max_cost_ratio']} "
          f"kernel_pick_rate={result['agreement']['kernel_pick_rate']}")
    print(f"wrote {args.out}")
    if not result["within_bounds"]:
        raise SystemExit(
            f"kernel level out of bounds: "
            f"local={result['local_kernel']['geomean_ratio']} "
            f"(<= {KERNEL_OVER_DOT_BOUND}), "
            f"routed={result['routed_modes']['geomean_ratio']} "
            f"(<= {ROUTED_KERNEL_BOUND}), "
            f"overlap={result['overlap']['geomean_ratio']} "
            f"(<= {OVERLAP_BOUND}), "
            f"inner_match={result['agreement']['inner_match_rate']} "
            f"(>= {INNER_MATCH_FLOOR}), "
            f"cost_ratio={result['agreement']['max_cost_ratio']} "
            f"(<= {COST_RATIO_BOUND}), "
            f"kernel_pick_rate={result['agreement']['kernel_pick_rate']} "
            f"(> 0)")
    return result


def run() -> List[str]:
    """benchmarks/run.py hook: subprocess so the fake-device XLA flag never
    leaks into the shared jax runtime of the other benchmarks."""
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--reps", "1",
         "--out", os.devnull],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH":
             os.pathsep.join(filter(None, [
                 os.path.join(os.path.dirname(__file__), "..", "src"),
                 os.environ.get("PYTHONPATH", "")]))})
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-500:])
    return [l for l in proc.stdout.splitlines() if l.startswith("kernel.")]


if __name__ == "__main__":
    main()
