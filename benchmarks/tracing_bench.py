"""Tracer-overhead benchmark -> BENCH_tracing.json (the cost of the
observability layer on the dispatch path).

Three measurements, each with an asserted bound so CI fails when the
tracer stops being cheap:

- **span emit latency**: `Tracer.span` per call on the pure-python path
  (no jax) — the fixed cost every traced `pmm` dispatch and serve step
  pays. Bound: < 50us/span (measured ~1-2us).
- **maybe_span no-op latency**: `obs.trace.maybe_span` with NO tracer
  installed — the cost untraced production code pays at every
  instrumented callsite. Informational (ns-scale), no bound beyond the
  dispatch ratio below, which already covers it end-to-end.
- **routed dispatch overhead**: jit trace time of `pmm` through a warmed
  planner on the 4x4 host mesh (the same routed harness the routing
  benchmark uses), tracer installed vs not. The tracer adds span
  bookkeeping plus the provenance digests (`plan.digest()`,
  `calibration_digest`) that are only computed when tracing. Bound:
  traced/untraced ratio < 1.25 (jit tracing is ms-scale; span emission is
  us-scale).

The result JSON carries a `within_bounds` flag; the bench itself raises
when a bound is violated, so both standalone runs and CI catch a
regression without parsing the numbers.

Standalone (sets its own fake-device count; run before importing jax
elsewhere):

  PYTHONPATH=src python benchmarks/tracing_bench.py --reps 3

Also exposed to benchmarks/run.py via a subprocess `run()` so the device
count does not leak into the other benchmarks' jax runtime.
"""
import argparse
import json
import os
import time
from typing import List

SPAN_EMIT_BOUND_US = 50.0
DISPATCH_OVERHEAD_BOUND = 1.25


def _bench_span_emit(n: int = 20_000) -> dict:
    """Pure-python span emission cost (no jax in the loop)."""
    from repro.obs import Tracer, set_tracer
    from repro.obs.trace import maybe_span

    tracer = Tracer(process_name="bench", max_events=n + 10)
    t0 = time.perf_counter()
    for i in range(n):
        with tracer.span("bench.span", tag="t", i=i):
            pass
    span_us = (time.perf_counter() - t0) / n * 1e6

    set_tracer(None)
    t0 = time.perf_counter()
    for i in range(n):
        with maybe_span("bench.noop", i=i):
            pass
    noop_ns = (time.perf_counter() - t0) / n * 1e9
    return {"span_emit_us": round(span_us, 3),
            "maybe_span_noop_ns": round(noop_ns, 1),
            "n": n}


def _bench_dispatch(reps: int) -> dict:
    """jit trace time of routed `pmm` with vs without a tracer installed.

    Fresh `jax.jit` wrappers per repetition keep every trace cold — a
    cached trace would measure dict lookup, not the dispatch path."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import smoke_config
    from repro.deploy import Planner, model_workload
    from repro.hw.config import tpu_pod_as_accelerator
    from repro.models import shard_ctx
    from repro.models.matmul import pmm
    from repro.obs import Tracer, set_tracer

    cfg = smoke_config("gemma-2b")
    hw = tpu_pod_as_accelerator((4, 4))
    planner = Planner(hw, max_candidates=8)
    workload = model_workload(cfg, batch=2, seq=16, kind="prefill")
    planner.batch_tune(workload)

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    ctx = shard_ctx.GemmContext(mesh=mesh, planner=planner)
    rng = np.random.default_rng(0)
    args = [(jnp.asarray(rng.standard_normal((s.m, s.k)), jnp.float32),
             jnp.asarray(rng.standard_normal((s.k, s.n)), jnp.float32))
            for s in dict.fromkeys(workload)]

    def trace_workload() -> float:
        t0 = time.perf_counter()
        with shard_ctx.gemm_context(ctx):
            for i, (a, b) in enumerate(args):
                fn = jax.jit(lambda x, w, t=f"bench.{i}": pmm(x, w, tag=t))
                fn.lower(a, b)
        return (time.perf_counter() - t0) / len(args) * 1e6

    # warm once (first trace pays jax setup costs neither side should own)
    trace_workload()

    untraced = traced = float("inf")
    for _ in range(max(1, reps)):
        set_tracer(None)
        untraced = min(untraced, trace_workload())
        set_tracer(Tracer(process_name="bench"))
        traced = min(traced, trace_workload())
    set_tracer(None)
    return {"workload_shapes": len(args),
            "untraced_dispatch_us": round(untraced, 1),
            "traced_dispatch_us": round(traced, 1),
            "overhead_ratio": round(traced / untraced, 3)}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3,
                    help="dispatch-trace repetitions (best-of)")
    ap.add_argument("--out", default="BENCH_tracing.json")
    args = ap.parse_args(argv)

    # must precede the first jax import (the lazy in-function imports
    # above); appended rather than set so a pre-existing XLA_FLAGS keeps
    # its settings alongside the fake-device count.
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=16").strip()

    result = _bench_span_emit()
    result.update(_bench_dispatch(args.reps))
    result["bounds"] = {"span_emit_us": SPAN_EMIT_BOUND_US,
                       "overhead_ratio": DISPATCH_OVERHEAD_BOUND}
    result["within_bounds"] = (
        result["span_emit_us"] < SPAN_EMIT_BOUND_US
        and result["overhead_ratio"] < DISPATCH_OVERHEAD_BOUND)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    print(f"tracing.span_emit,{result['span_emit_us']},"
          f"noop_ns={result['maybe_span_noop_ns']}")
    print(f"tracing.dispatch,{result['traced_dispatch_us']},"
          f"untraced={result['untraced_dispatch_us']} "
          f"ratio={result['overhead_ratio']}")
    print(f"wrote {args.out}")
    if not result["within_bounds"]:
        raise SystemExit(
            f"tracing overhead out of bounds: "
            f"span_emit_us={result['span_emit_us']} "
            f"(< {SPAN_EMIT_BOUND_US}), "
            f"overhead_ratio={result['overhead_ratio']} "
            f"(< {DISPATCH_OVERHEAD_BOUND})")
    return result


def run() -> List[str]:
    """benchmarks/run.py hook: subprocess so the fake-device XLA flag never
    leaks into the shared jax runtime of the other benchmarks."""
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--reps", "1",
         "--out", os.devnull],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH":
             os.pathsep.join(filter(None, [
                 os.path.join(os.path.dirname(__file__), "..", "src"),
                 os.environ.get("PYTHONPATH", "")]))})
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-500:])
    return [l for l in proc.stdout.splitlines() if l.startswith("tracing.")]


if __name__ == "__main__":
    main()
