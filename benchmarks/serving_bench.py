"""SLO serving benchmark under replayed traffic -> BENCH_serving.json.

The gate for the serving harness (`launch/traffic.py` + `deploy/batcher.py`)
— where the paper's per-GEMM wins are measured against *live traffic*
instead of one fixed batch. One seeded multi-tenant trace (two tenants,
two different model configs — gemma-2b + olmo-1b smoke — sharing one
planner, deliberately ragged odd prompt lengths) is replayed twice through
the virtual-clock continuous-batching loop against the pod-view planner:

- **bucket**: bucket-aware admission. Every batched GEMM M lands on the
  warmed pow-2 pool, so the replay is all plan-cache hits — zero cold
  shapes, zero virtual compile charges.
- **fifo**: the naive baseline. Admission fragments M into the long tail,
  and every fresh M pays the cold price (compile + bucketed transfer /
  online analytic tune) on the virtual clock.

Asserted bounds (the artifact's `bounds` section; `within_bounds` is the
single flag CI re-asserts):

- bucket goodput >= GOODPUT_FLOOR tokens/s (SLO-met tokens over makespan);
- bucket p99 total latency <= P99_BOUND_S;
- bucket plan-resolve rate >= RESOLVE_FLOOR (and fifo's too: raggedness
  must degrade latency, never correctness — bucketed transfers + the
  online analytic tuner still resolve every shape);
- bucket cold shapes == 0 (admission never leaves the warmed pool);
- bucket goodput >= fifo goodput on the SAME trace (the win is real).

  PYTHONPATH=src python benchmarks/serving_bench.py

Pure virtual-clock + cost-model arithmetic — no jax, no devices, fully
deterministic. docs/serving.md describes the traffic model; the artifact
schema is in docs/benchmarking.md.
"""
import argparse
import json
from typing import List

# Asserted bounds. Headroom note: at seed 7 the bucket run measures
# ~11k tok/s goodput with p99 ~51 ms and the fifo baseline collapses to ~0
# goodput (40 cold shapes' compile charges blow every deadline), so the
# floors below carry ~5x margin against cost-model recalibrations.
GOODPUT_FLOOR = 2000.0      # tokens/s, bucket run
P99_BOUND_S = 0.25          # total-latency p99, bucket run
RESOLVE_FLOOR = 1.0         # plan-resolve rate, BOTH runs
SEED = 7


def _traffic():
    from repro.launch.traffic import TenantSpec, TrafficConfig
    # odd, pow-2-straddling prompt lengths: exactly the ragged stream that
    # fragments naive admission (13+29=42 -> bucket 64; 47 -> 64; ...)
    return TrafficConfig(seed=SEED, tenants=(
        TenantSpec(name="gemma", arch="gemma-2b", rate_rps=200.0,
                   n_requests=24, prompt_lens=(13, 29, 47, 61),
                   gen_lens=(2, 3, 5)),
        TenantSpec(name="olmo", arch="olmo-1b", rate_rps=150.0,
                   n_requests=16, prompt_lens=(11, 23, 37),
                   gen_lens=(2, 4)),
    ))


def _replay(trace, tcfg, cfgs, mode: str, max_candidates: int) -> dict:
    from repro.deploy.batcher import BatchPolicy
    from repro.deploy.planner import Planner
    from repro.hw.config import tpu_pod_as_accelerator
    from repro.launch.traffic import serving_section, simulate, warm_pool
    policy = BatchPolicy(mode=mode)
    # a FRESH planner per mode: the fifo baseline must not inherit the
    # bucket run's online-tuned entries (or vice versa)
    planner = Planner(tpu_pod_as_accelerator((4, 4)),
                      max_candidates=max_candidates)
    warmed = warm_pool(planner, cfgs, policy, tcfg.max_rows(policy))
    result = simulate(trace, planner, cfgs, policy=policy,
                      precompiled=warmed)
    section = serving_section(result)
    section["warmed_pool"] = len(warmed)
    return section


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan-candidates", type=int, default=8,
                    help="autotuner width for the warm-up tunes (the "
                         "runtime knob)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    from repro.configs import smoke_config
    from repro.launch.traffic import generate_trace

    tcfg = _traffic()
    trace = generate_trace(tcfg)
    cfgs = {t.name: smoke_config(t.arch) for t in tcfg.tenants}

    result = {"seed": tcfg.seed,
              "trace": {"requests": len(trace),
                        "tenants": [t.name for t in tcfg.tenants],
                        "archs": sorted({c.name for c in cfgs.values()})},
              "bounds": {"goodput_floor": GOODPUT_FLOOR,
                         "p99_bound_s": P99_BOUND_S,
                         "resolve_floor": RESOLVE_FLOOR,
                         "bucket_cold_shapes": 0},
              "runs": {}}
    for mode in ("bucket", "fifo"):
        section = _replay(trace, tcfg, cfgs, mode, args.plan_candidates)
        result["runs"][mode] = section
        print(f"serving.{mode},{section['p99_latency_s'] * 1e6:.1f},"
              f"goodput={section['goodput_tps']:.1f} "
              f"p99={section['p99_latency_s'] * 1e3:.1f}ms "
              f"miss={section['deadline_miss_rate']:.0%} "
              f"cold={section['cold_shapes']} "
              f"resolve={section['resolve_rate']:.3f} "
              f"util={section['mean_batch_utilization']:.2f}", flush=True)

    bucket, fifo = result["runs"]["bucket"], result["runs"]["fifo"]
    result["bucket_vs_fifo_goodput"] = (
        bucket["goodput_tps"] / fifo["goodput_tps"]
        if fifo["goodput_tps"] else float("inf"))
    violations = []
    if bucket["goodput_tps"] < GOODPUT_FLOOR:
        violations.append(f"bucket goodput_tps="
                          f"{bucket['goodput_tps']:.1f} < {GOODPUT_FLOOR}")
    if bucket["p99_latency_s"] > P99_BOUND_S:
        violations.append(f"bucket p99_latency_s="
                          f"{bucket['p99_latency_s']:.4f} > {P99_BOUND_S}")
    for mode in ("bucket", "fifo"):
        rate = result["runs"][mode]["resolve_rate"]
        if rate < RESOLVE_FLOOR:
            violations.append(f"{mode} resolve_rate={rate:.3f} "
                              f"< {RESOLVE_FLOOR}")
    if bucket["cold_shapes"] != 0:
        violations.append(f"bucket cold_shapes={bucket['cold_shapes']} "
                          f"!= 0 — admission left the warmed pool")
    if bucket["goodput_tps"] < fifo["goodput_tps"]:
        violations.append(f"bucket goodput {bucket['goodput_tps']:.1f} < "
                          f"fifo baseline {fifo['goodput_tps']:.1f}")
    result["within_bounds"] = not violations
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    if violations:
        raise SystemExit("serving harness out of bounds: "
                         + "; ".join(violations))
    return result


def run() -> List[str]:
    """benchmarks/run.py hook — narrower warm-up tunes keep the CSV sweep
    fast; the standalone/CI invocation owns the full-width gate."""
    import contextlib
    import io
    import os
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf):
            main(["--plan-candidates", "6", "--out", os.devnull])
    except SystemExit as e:
        # run.py's per-module handler catches Exception, not SystemExit
        raise RuntimeError(str(e))
    return [l for l in buf.getvalue().splitlines()
            if l.startswith("serving.")]


if __name__ == "__main__":
    main()
