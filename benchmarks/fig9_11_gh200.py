"""Paper Figs. 9-11: GEMM performance on the GH200-sized SoftHier instance
over the DeepSeek-V3 (DeepGEMM) shapes, with the autotuner selecting the best
schedule per shape exactly as §4.1.4 describes ('we iterate through our
predefined schedule candidates, guided by the insights above').

Fig. 9: compute-bound shapes -> TFLOPS + speedup vs the GH200 reference.
Fig. 10/11: flat shapes -> TFLOPS + HBM bandwidth utilization.

The GH200 columns are external reference constants (see benchmarks.common);
the paper's claims to reproduce are speedup bands 1.2-1.5x (compute) and
1.2-2.0x (flat).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import (A100_REF_UTIL_COMPUTE, COMPUTE_BOUND, FLAT,
                               GH200_REF_UTIL_COMPUTE, GH200_REF_UTIL_FLAT_BW,
                               csv_row)
from repro.core.autotuner import tune
from repro.hw.config import softhier_gh200
from repro.sim.perf import estimate

HW = softhier_gh200()


def run() -> List[str]:
    rows = []
    speedups_c = []
    for shape in COMPUTE_BOUND:
        t0 = time.perf_counter()
        res = tune(shape, HW, elem_bytes=1, max_candidates=24)
        us = (time.perf_counter() - t0) * 1e6
        util = res.report.utilization(HW)
        ref_tflops = GH200_REF_UTIL_COMPUTE * HW.peak_flops / 1e12
        speedup = (res.report.achieved_flops / 1e12) / ref_tflops
        speedups_c.append(speedup)
        rows.append(csv_row(
            f"fig9.M{shape.m}.N{shape.n}.K{shape.k}", us,
            f"TFLOPS={res.report.achieved_flops/1e12:.0f};"
            f"util={util*100:.1f}%;vsGH200=x{speedup:.2f};"
            f"sched={res.schedule.dataflow}[{res.schedule.tiling.gm}x"
            f"{res.schedule.tiling.gn}x{res.schedule.tiling.gk}]"))
    rows.append(csv_row(
        "fig9.speedup_range", 0.0,
        f"x{min(speedups_c):.2f}-x{max(speedups_c):.2f};paper_claims=x1.2-1.5"))

    speedups_f = []
    for shape in FLAT:
        t0 = time.perf_counter()
        res = tune(shape, HW, elem_bytes=1, max_candidates=24)
        us = (time.perf_counter() - t0) * 1e6
        bw = res.report.bw_utilization(HW)
        # flat GEMM is bandwidth-bound: compare achieved bandwidth share
        speedup = bw / GH200_REF_UTIL_FLAT_BW
        speedups_f.append(speedup)
        rows.append(csv_row(
            f"fig10_11.M{shape.m}.N{shape.n}.K{shape.k}", us,
            f"TFLOPS={res.report.achieved_flops/1e12:.1f};"
            f"bw_util={bw*100:.1f}%;vsGH200=x{speedup:.2f};"
            f"sched={res.schedule.dataflow}[{res.schedule.tiling.gm}x"
            f"{res.schedule.tiling.gn}x{res.schedule.tiling.gk}]"))
    rows.append(csv_row(
        "fig10_11.speedup_range", 0.0,
        f"x{min(speedups_f):.2f}-x{max(speedups_f):.2f};paper_claims=x1.2-2.0"))
    return rows
