"""Analytic-shortlist rank agreement benchmark -> BENCH_analytic.json.

The gate for `repro.core.analytic` — the closed-form candidate generator the
planner's online-tuning path trusts on every `plan_cached` miss. Three
suites, each a dense GEMMShape grid compared against the exhaustive
`core.autotuner.tune` optimum:

- **mini_identity**: the mini accelerator under the analytical prior.
  Asserted: top-1 agreement >= 0.9, shortlist-best cost <= 1.05x optimum
  everywhere, mean shortlist generation < 1 ms (worst shape < 2.5 ms).
- **mini_calibrated**: same grid under a trusted CalibrationProfile (scaled
  compute/DMA/NoC terms — the regime a fitted profile puts the ranking in,
  which also widens the search space to the hierarchical dataflows).
  Asserted with the same bounds — the generator must track the objective it
  is derived from, not just the default one.
- **pod_identity**: the tpu-pod-view accelerator (the deploy layer's
  serving hardware) — a stress suite over a different engine geometry and
  element width. Reported with a looser top-1 floor (the DMA-bound corner
  of this machine misranks inside the shortlist's tie band) but the SAME
  <=1.05x cost-ratio bound: even a top-1 miss must cost within 5% of the
  optimum.

The result JSON carries per-suite summaries + per-shape records and a
`within_bounds` flag; the bench raises when any bound is violated, so both
standalone runs and CI catch a regression without parsing the numbers.

  PYTHONPATH=src python benchmarks/analytic_bench.py

Pure cost-model arithmetic — no jax, no devices. The exhaustive baseline
dominates the runtime (seconds per shape at --max-exhaustive 256); the
shortlist side is the microseconds being measured.
"""
import argparse
import json
from typing import List

# Asserted bounds (mini suites). POD is a stress suite: the cost-ratio and
# generation-latency bounds still bind, the top-1 floor is looser.
# Generation latency is bounded on the MEAN (the sub-millisecond claim:
# amortized shortlist derivation per serving miss) with a separate tail
# guard on the worst shape — a full 32-candidate shortlist costs ~2.5k
# Python calls, so the per-shape max tracks interpreter dispatch, not
# algorithmic regressions.
TOP1_BOUND = 0.90
COST_RATIO_BOUND = 1.05
MEAN_GEN_US_BOUND = 1000.0
MAX_GEN_US_BOUND = 2500.0
POD_TOP1_FLOOR = 0.60


def _mini_hw():
    from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                                 TileConfig)
    return AcceleratorConfig(name="mini", grid=(4, 4),
                             tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                             noc=NoCConfig(), hbm=HBMConfig(n_channels=8))


def _mini_profile(hw):
    """A trusted profile with deliberately skewed terms: compute priced up,
    DMA down, NoC up — enough to flip winners (fp32 accumulators and
    degenerate grids start paying off), so agreement under it is a real
    test of calibrated derivation, not a repeat of the identity suite."""
    from repro.deploy.plan import hw_fingerprint
    from repro.sim.calibrate import CalibrationProfile
    return CalibrationProfile(hw_name=hw.name, hw_digest=hw_fingerprint(hw),
                              compute_scale=1.35, dma_scale=0.8,
                              noc_scale=1.25, step_overhead_s=1e-6,
                              n_samples=12, r2=0.97, fit_ok=True)


def _suites(max_exhaustive: int):
    from repro.core.schedule import GEMMShape
    from repro.hw.config import tpu_pod_as_accelerator
    mini = _mini_hw()
    pod = tpu_pod_as_accelerator((4, 4))
    mini_grid = [GEMMShape(m, n, k)
                 for m in (256, 512, 1024, 4096)
                 for n in (256, 1024, 4096)
                 for k in (256, 1024, 8192)]
    pod_grid = [GEMMShape(m, n, k)
                for m in (512, 2048, 8192)
                for n in (1024, 4096)
                for k in (1024, 8192)]
    return [
        {"suite": "mini_identity", "hw": mini, "shapes": mini_grid,
         "elem_bytes": 1, "calibration": None, "top1_bound": TOP1_BOUND},
        {"suite": "mini_calibrated", "hw": mini, "shapes": mini_grid,
         "elem_bytes": 1, "calibration": _mini_profile(mini),
         "top1_bound": TOP1_BOUND},
        {"suite": "pod_identity", "hw": pod, "shapes": pod_grid,
         "elem_bytes": 2, "calibration": None, "top1_bound": POD_TOP1_FLOOR},
    ]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-exhaustive", type=int, default=256,
                    help="exhaustive-search width the shortlist is judged "
                         "against (the runtime knob: seconds per shape)")
    ap.add_argument("--out", default="BENCH_analytic.json")
    args = ap.parse_args(argv)

    from repro.core.analytic import agreement_stats

    result = {"max_exhaustive": args.max_exhaustive, "suites": {},
              "bounds": {"top1_rate": TOP1_BOUND,
                         "pod_top1_floor": POD_TOP1_FLOOR,
                         "max_cost_ratio": COST_RATIO_BOUND,
                         "mean_gen_us": MEAN_GEN_US_BOUND,
                         "max_gen_us": MAX_GEN_US_BOUND}}
    violations = []
    for spec in _suites(args.max_exhaustive):
        stats = agreement_stats(spec["shapes"], spec["hw"],
                                elem_bytes=spec["elem_bytes"],
                                calibration=spec["calibration"],
                                max_exhaustive=args.max_exhaustive)
        result["suites"][spec["suite"]] = stats
        if stats["top1_rate"] < spec["top1_bound"]:
            violations.append(f"{spec['suite']}: top1_rate="
                              f"{stats['top1_rate']:.3f} "
                              f"< {spec['top1_bound']}")
        if stats["max_cost_ratio"] > COST_RATIO_BOUND:
            violations.append(f"{spec['suite']}: max_cost_ratio="
                              f"{stats['max_cost_ratio']:.4f} "
                              f"> {COST_RATIO_BOUND}")
        if stats["mean_gen_us"] >= MEAN_GEN_US_BOUND:
            violations.append(f"{spec['suite']}: mean_gen_us="
                              f"{stats['mean_gen_us']:.0f} "
                              f">= {MEAN_GEN_US_BOUND}")
        if stats["max_gen_us"] >= MAX_GEN_US_BOUND:
            violations.append(f"{spec['suite']}: max_gen_us="
                              f"{stats['max_gen_us']:.0f} "
                              f">= {MAX_GEN_US_BOUND}")
        print(f"analytic.{spec['suite']},{stats['mean_gen_us']},"
              f"top1={stats['top1_rate']:.3f} "
              f"max_ratio={stats['max_cost_ratio']:.4f} "
              f"max_gen_us={stats['max_gen_us']:.0f} "
              f"speedup_vs_exhaustive={stats['mean_speedup_vs_exhaustive']:.0f}x",
              flush=True)
    result["within_bounds"] = not violations
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out}")
    if violations:
        raise SystemExit("analytic shortlist out of bounds: "
                         + "; ".join(violations))
    return result


def run() -> List[str]:
    """benchmarks/run.py hook — narrower exhaustive baseline keeps the CSV
    sweep fast; the standalone/CI invocation owns the full-width gate."""
    import contextlib
    import io
    import os
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf):
            main(["--max-exhaustive", "64", "--out", os.devnull])
    except SystemExit as e:
        # run.py's per-module handler catches Exception, not SystemExit
        raise RuntimeError(str(e))
    return [l for l in buf.getvalue().splitlines()
            if l.startswith("analytic.")]


if __name__ == "__main__":
    main()
