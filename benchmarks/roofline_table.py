"""Roofline benchmark: renders the §Roofline table from the dry-run artifacts
(results/dryrun/*.json). Produces one CSV row per (arch x shape) cell with the
three terms, the dominant bottleneck, and the MODEL_FLOPS ratio."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import csv_row

RESULTS = os.environ.get("DIT_DRYRUN_DIR", "results/dryrun")


def run() -> List[str]:
    rows = []
    files = sorted(glob.glob(os.path.join(RESULTS, "*__sp.json")))
    if not files:
        return [csv_row("roofline.missing", 0.0,
                        f"no dry-run artifacts under {RESULTS}")]
    for path in files:
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok" or "roofline" not in r:
            rows.append(csv_row(
                f"roofline.{r.get('arch')}.{r.get('shape')}", 0.0,
                f"status={r.get('status')}:{str(r.get('error'))[:60]}"))
            continue
        rf = r["roofline"]
        acc = r["accounting"]
        rows.append(csv_row(
            f"roofline.{r['arch']}.{r['shape']}", r.get("elapsed_s", 0) * 1e6,
            f"compute_s={rf['compute_s']:.4f};memory_s={rf['memory_s']:.4f};"
            f"collective_s={rf['collective_s']:.4f};dominant={rf['dominant']};"
            f"frac={rf['roofline_fraction']:.3f};"
            f"useful={acc['useful_ratio']:.2f};"
            f"peakGB={r['full']['peak_bytes_per_device']/1e9:.1f}"))
    return rows
