"""Cold vs warm deployment planning (the deploy/ subsystem's headline).

Cold path: full candidate search per shape (what the paper's toolchain does
once per deployment). Warm path: PlanCache hit — no enumeration, no pricing.
Bucketed path: an untuned shape served by adapting the nearest tuned bucket,
reported as estimated-time ratio vs a fresh tune (tolerance target: 1.25).

Rows:
  plan.cold_tune,<us per shape>,shapes=N
  plan.warm_hit,<us per shape>,speedup=<cold/warm>x
  plan.bucketed.<MxNxK>,<us lookup>,ratio=<est/fresh>
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import DEEPSEEK_NK
from repro.core.schedule import GEMMShape
from repro.deploy import PlanCache, Planner
from repro.hw.config import softhier_gh200

# three compute-bound DeepSeek projection shapes (M = 4096 tokens)
TUNE_SHAPES = [GEMMShape(4096, n, k) for (n, k) in DEEPSEEK_NK[:3]]
# untuned probes: same workload family, one dimension perturbed — the kind
# of near-miss serving traffic the bucketing layer exists for.
PROBE_SHAPES = [GEMMShape(4096, 2112, 3584),
                GEMMShape(4096, 1056, 7168),
                GEMMShape(4096, 24576, 3072)]


def run() -> List[str]:
    hw = softhier_gh200()
    planner = Planner(hw, cache=PlanCache(), elem_bytes=1, max_candidates=8)

    t0 = time.perf_counter()
    planner.batch_tune(TUNE_SHAPES)
    cold_us = (time.perf_counter() - t0) / len(TUNE_SHAPES) * 1e6

    t0 = time.perf_counter()
    for shape in TUNE_SHAPES:
        planner.plan(shape)
    warm_us = (time.perf_counter() - t0) / len(TUNE_SHAPES) * 1e6

    rows = [
        f"plan.cold_tune,{cold_us:.1f},shapes={len(TUNE_SHAPES)}",
        f"plan.warm_hit,{warm_us:.1f},speedup={cold_us / warm_us:.0f}x",
    ]
    for shape in PROBE_SHAPES:
        t0 = time.perf_counter()
        plan = planner.plan(shape)
        lookup_us = (time.perf_counter() - t0) * 1e6
        fresh = planner._tune_shape(shape)
        ratio = plan.report.total_time / fresh.report.total_time
        rows.append(f"plan.bucketed.{shape.m}x{shape.n}x{shape.k},"
                    f"{lookup_us:.1f},source={plan.source} ratio={ratio:.3f}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
