"""Routing-path benchmark -> BENCH_routing.json (the perf trajectory of the
schedule->mesh lowering layer).

Four measurements on a model workload (smoke config, 16 fake CPU devices):

- **plan-resolve latency**: `Planner.plan_cached` per workload shape against
  a warmed cache (the trace-time dispatch cost every `pmm` callsite pays),
  plus `lower_schedule` per served plan (the ExecPlan resolution cost).
- **per-mode trace+lower wall time**: `jax.jit(dit_gemm).lower()` for every
  executed mode — auto baseline, summa, cannon, 1-D/3-D split-K, both
  reduction owners, both hierarchical compositions — the compile-side price
  of honoring the tuned dataflow instead of letting XLA place collectives.
- **fallback rate**: fraction of the workload's tuned plans that degrade to
  `auto` when lowered onto the mesh, with per-reason counts and the
  silent-degrade cross-check (must be 0: every degrade carries a reason).
- **per-mode execution efficiency vs XLA auto**: each executable mode
  (summa, cannon, splitk_summa, hierarchical, outer_systolic) runs the same
  GEMM set on a 4x4 host mesh, best-of-reps wall time against the `auto`
  baseline; `efficiency_vs_auto > 1` means the tuned collective pattern
  beat XLA's placement. This is the ground-truth signal the autotuner's
  simulator-side perf reports are validated against (on fake CPU devices
  the absolute numbers measure collective-schedule overhead, not real
  fabric bandwidth — see docs/benchmarking.md for the methodology and what
  a regression means).

Standalone (sets its own fake-device count; run before importing jax
elsewhere):

  PYTHONPATH=src python benchmarks/routing_bench.py --reps 1

Also exposed to benchmarks/run.py via a subprocess `run()` so the device
count does not leak into the other benchmarks' jax runtime.
"""
import argparse
import json
import os
import time
from typing import List


def _bench() -> dict:
    import jax

    from repro.configs import smoke_config
    from repro.core.lower import lower_schedule, lowering_summary
    from repro.deploy import Planner, model_workload
    from repro.hw.config import tpu_pod_as_accelerator

    cfg = smoke_config("gemma-2b")
    hw = tpu_pod_as_accelerator((4, 4))
    planner = Planner(hw, max_candidates=8)
    workload = model_workload(cfg, batch=2, seq=16, kind="prefill")

    t0 = time.perf_counter()
    planner.batch_tune(workload)
    tune_us = (time.perf_counter() - t0) / len(workload) * 1e6

    t0 = time.perf_counter()
    plans = [planner.plan_cached(s) for s in workload]
    resolve_us = (time.perf_counter() - t0) / len(workload) * 1e6

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    t0 = time.perf_counter()
    eps = [lower_schedule(p.schedule, mesh, shape=s)
           for s, p in zip(workload, plans)]
    lower_us = (time.perf_counter() - t0) / len(workload) * 1e6
    summary = lowering_summary(eps)
    summary["fallback_rate"] = (summary["degraded"] / summary["total"]
                                if summary["total"] else 0.0)
    return {
        "workload_shapes": len(workload),
        "plan_cold_tune_us": round(tune_us, 1),
        "plan_resolve_us": round(resolve_us, 1),
        "lower_schedule_us": round(lower_us, 1),
        "workload_lowering": summary,
    }


def _bench_modes(reps: int) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.gemm import dit_gemm
    from repro.core.schedule import GEMMShape, Schedule, Tiling

    # 4x4: square, so `systolic` traces cannon and `systolic_over_summa`
    # traces the real outer_systolic mode instead of their fallbacks
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    M, N, K = 256, 256, 512
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)

    cases = [("auto", None)]
    for df, gk, owner in (("summa", 1, "first"),
                          ("systolic", 1, "first"),
                          ("baseline", 1, "first"),
                          ("splitk_summa", 2, "round_robin"),
                          ("splitk_summa", 2, "first"),
                          ("splitk_summa", 16, "round_robin"),  # 1-D collapse
                          ("summa_over_systolic", 1, "first"),
                          ("systolic_over_summa", 1, "first")):
        sched = Schedule(GEMMShape(M, N, K), Tiling(2, 2, gk, tk=64), df,
                         reduce_owner=owner, inner=(2, 2))
        label = df if gk <= 2 else f"{df}_1d"
        if df == "splitk_summa" and gk == 2:
            label += f"_{owner}"
        cases.append((label, sched))

    out = {}
    for label, sched in cases:
        if sched is None:
            fn = jax.jit(lambda x, y: dit_gemm(x, y, mesh, mode="auto"))
        else:
            fn = jax.jit(lambda x, y, s=sched: dit_gemm(x, y, mesh, plan=s))
        best = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            fn.lower(a, b)
            best = min(best, time.perf_counter() - t0)
        out[label] = round(best * 1e3, 2)
    return out


def _bench_efficiency(reps: int) -> dict:
    """Per-mode execution wall time vs XLA auto on a 4x4 host mesh.

    The 4x4 grid is the smallest square mesh on which EVERY executable mode
    — including the Fig. 6c outer-systolic composition (2x2 outer ring of
    2x2 inner groups) — lowers without fallback, so all modes run the same
    GEMM set. Every schedule's lowering is asserted clean before timing: a
    silent degrade would quietly benchmark `auto` against itself.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.gemm import dit_gemm
    from repro.core.lower import lower_schedule
    # the mode-case table, schedule construction, and timing discipline are
    # shared with the calibration harness (sim/calibrate.measure_modes) so
    # a new executable mode lands in both measured surfaces together
    from repro.sim.calibrate import (MODE_CASES, build_mode_schedule,
                                     time_best_of)

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    gemms = [(256, 256, 512), (512, 256, 1024)]
    rng = np.random.default_rng(0)

    auto_ms = []
    modes = {label: {"ms": [], "efficiency_vs_auto": []}
             for label, _, _ in MODE_CASES}
    for (M, N, K) in gemms:
        a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
        t_auto = time_best_of(jax.jit(
            lambda x, y: dit_gemm(x, y, mesh, mode="auto")), a, b, reps)
        auto_ms.append(round(t_auto * 1e3, 3))
        for label, df, kw in MODE_CASES:
            sched = build_mode_schedule(df, kw, 4, 4, (M, N, K))
            ep = lower_schedule(sched, mesh, shape=(M, N, K))
            if ep.mode != label or ep.degraded:
                raise RuntimeError(f"{df} lowered to {ep.describe()}, "
                                   f"expected clean {label}")
            t = time_best_of(jax.jit(
                lambda x, y, s=sched: dit_gemm(x, y, mesh, plan=s)), a, b,
                reps)
            modes[label]["ms"].append(round(t * 1e3, 3))
            modes[label]["efficiency_vs_auto"].append(round(t_auto / t, 3))
    for rec in modes.values():
        effs = rec["efficiency_vs_auto"]
        rec["geomean"] = round(
            float(np.exp(np.mean(np.log(np.asarray(effs))))), 3)
    return {
        "mesh": [4, 4],
        "gemms": [list(g) for g in gemms],
        "auto_ms": auto_ms,
        "modes": modes,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3,
                    help="trace+lower / execution repetitions per mode "
                         "(best-of)")
    ap.add_argument("--skip-efficiency", action="store_true",
                    help="skip the per-mode execution timing (keep only the "
                         "trace-time measurements)")
    ap.add_argument("--out", default="BENCH_routing.json")
    args = ap.parse_args(argv)

    # must precede the first jax import (the lazy in-function imports below);
    # set here, not at module top, so merely importing this module (e.g.
    # from benchmarks/run.py) cannot leak fake devices into the host
    # process. Appended rather than set so a pre-existing XLA_FLAGS (dump
    # dirs etc.) keeps its settings alongside the fake-device count.
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=16").strip()
    result = _bench()
    result["trace_lower_ms"] = _bench_modes(args.reps)
    if not args.skip_efficiency:
        result["efficiency_vs_auto"] = _bench_efficiency(args.reps)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    wl = result["workload_lowering"]
    print(f"routing.plan_resolve,{result['plan_resolve_us']},"
          f"shapes={result['workload_shapes']} "
          f"cold={result['plan_cold_tune_us']}")
    print(f"routing.lower_schedule,{result['lower_schedule_us']},"
          f"fallback_rate={wl['fallback_rate']:.2f} "
          f"silent={wl['silent_auto_degrades']}")
    for label, ms in sorted(result["trace_lower_ms"].items()):
        print(f"routing.trace_lower.{label},{ms * 1e3:.1f},ms={ms}")
    for label, rec in sorted(result.get("efficiency_vs_auto",
                                        {}).get("modes", {}).items()):
        print(f"routing.exec.{label},{rec['ms'][0] * 1e3:.1f},"
              f"eff_vs_auto={rec['geomean']}")
    print(f"wrote {args.out}")
    return result


def run() -> List[str]:
    """benchmarks/run.py hook: subprocess so the fake-device XLA flag never
    leaks into the shared jax runtime of the other benchmarks."""
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--reps", "1",
         "--out", os.devnull],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH":
             os.pathsep.join(filter(None, [
                 os.path.join(os.path.dirname(__file__), "..", "src"),
                 os.environ.get("PYTHONPATH", "")]))})
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-500:])
    return [l for l in proc.stdout.splitlines() if l.startswith("routing.")]


if __name__ == "__main__":
    main()
