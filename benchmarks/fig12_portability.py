"""Paper Fig. 12 + §4.2: portability — the same DiT deployment sustains high
utilization on an A100-sized SoftHier instance AND the GH200-sized one, while
CUTLASS utilization (external reference) drops from A100 to GH200."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import (A100_REF_UTIL_COMPUTE, COMPUTE_BOUND,
                               GH200_REF_UTIL_COMPUTE, csv_row)
from repro.core.autotuner import tune
from repro.hw.config import softhier_a100, softhier_gh200


def run() -> List[str]:
    rows = []
    for hw, ref_util, ref_name in ((softhier_a100(), A100_REF_UTIL_COMPUTE, "A100"),
                                   (softhier_gh200(), GH200_REF_UTIL_COMPUTE, "GH200")):
        utils = []
        for shape in COMPUTE_BOUND[:4]:
            t0 = time.perf_counter()
            res = tune(shape, hw, elem_bytes=hw.tile.elem_bytes,
                       max_candidates=16)
            us = (time.perf_counter() - t0) * 1e6
            util = res.report.utilization(hw)
            utils.append(util)
            rows.append(csv_row(
                f"fig12.{hw.name}.M{shape.m}N{shape.n}K{shape.k}", us,
                f"util={util*100:.1f}%;ref_{ref_name}_cutlass={ref_util*100:.0f}%"))
        avg = sum(utils) / len(utils)
        rows.append(csv_row(
            f"fig12.{hw.name}.avg", 0.0,
            f"util={avg*100:.1f}%;cutlass_ref={ref_util*100:.0f}%;"
            f"sustains={'yes' if avg > 0.5 else 'below-ref'}"))
    return rows
