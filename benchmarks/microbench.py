"""Host microbenchmarks: the Pallas MMAD kernel (interpret mode, CPU) against
the jnp oracle, the functional SoftHier simulator, and tiny-arch train-step
wall time — the 'runs on a laptop' sanity row for each moving part."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timeit


def run() -> List[str]:
    rows = []
    rng = np.random.default_rng(0)

    # pallas mmad (interpret) vs oracle
    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    from repro.kernels.mmad import mmad
    from repro.kernels.ref import mmad_ref
    us_k = timeit(lambda: jax.block_until_ready(
        mmad(a, b, block_shape=(128, 128, 128), interpret=True)), reps=2)
    us_r = timeit(lambda: jax.block_until_ready(mmad_ref(a, b)), reps=5)
    rows.append(csv_row("micro.mmad_pallas_interpret_256", us_k, "CPU-interpret"))
    rows.append(csv_row("micro.mmad_ref_256", us_r, "jnp-oracle"))

    # functional simulator GEMM (verification path)
    from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
    from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
    from repro.sim.softhier import run_gemm
    hw = AcceleratorConfig(name="mini", grid=(4, 4),
                           tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                           noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
    prog = build_program(Schedule(GEMMShape(64, 64, 128),
                                  Tiling(4, 4, 1, tk=32), "summa"), hw)
    am = rng.standard_normal((64, 128)).astype(np.float32)
    bm = rng.standard_normal((128, 64)).astype(np.float32)
    us_sim = timeit(lambda: run_gemm(prog, am, bm), reps=2)
    rows.append(csv_row("micro.sim_functional_summa_4x4", us_sim, "numpy-BSP"))

    # smoke train step
    from repro.configs import smoke_config
    from repro.models.model import init_params
    from repro.optim import adamw
    from repro.train.steps import make_train_step
    cfg = smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    ostate = adamw.init(params)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig()))
    batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "targets": jnp.zeros((4, 64), jnp.int32)}
    step(params, ostate, None, batch)   # compile
    us_t = timeit(lambda: jax.block_until_ready(
        step(params, ostate, None, batch)[3]["loss"]), reps=3)
    rows.append(csv_row("micro.train_step_olmo_smoke", us_t,
                        f"tok/s={4*64/(us_t/1e6):,.0f}"))
    return rows
