"""Fused-attention benchmark -> BENCH_attention.json.

The gate for the FlatAttention dataflow (core/attention.py +
models.matmul.pattn): on a 4x4 fake-device mesh,

- **planner resolution**: every bench shape (GQA/MQA prefill, long-KV
  decode, MLA-absorbed decode geometry) must resolve through
  `Planner.plan_cached` to a fused `AttnSchedule` — resolve rate 1.0 —
  and `lower_attention` must come back CLEAN (a flat_* mode, no degrades);
  a shape that silently fell to `unfused_attn` would quietly benchmark
  the reference path against itself, so the harness raises instead.
- **fused_vs_unfused geomean** (the headline CI asserts >= 1.0): the cost
  model's prediction for the planner-picked fused schedule
  (`sim.perf.estimate_attention` — KV streamed through L1, one combine /
  ring superstep sequence over the mesh) against the same machine's
  unfused price, where the (Sq, Skv) score matrix round-trips HBM between
  QK^T, softmax, and PV and nothing shards over the mesh. Deterministic
  pure arithmetic — this is the deployment claim the dataflow exists for.
- **measured wall time**: fused `flat_attention` vs the unfused reference
  (`_sdpa`) on the fake mesh, best-of-reps. On fake CPU devices (one
  host core) this measures collective/trace overhead, not fabric
  parallelism — same caveat as BENCH_routing's efficiency_vs_auto — so
  the ratios are reported and asserted > 0, not >= 1.

Standalone (sets its own fake-device count; run before importing jax
elsewhere):

  PYTHONPATH=src python benchmarks/attention_bench.py --reps 1

Also exposed to benchmarks/run.py via a subprocess `run()` so the device
count does not leak into the other benchmarks' jax runtime.
"""
import argparse
import json
import os
import time
from typing import List

GEOMEAN_FLOOR = 1.0     # predicted fused-vs-unfused, geomean over shapes

# (label, b, sq, skv, h, hkv, d, dv) — prefill + decode geometries; every
# skv divides the 4-row mesh axis so the fused lowering is clean
SHAPES = [
    ("prefill_mha", 1, 1024, 1024, 8, 8, 64, 64),
    ("prefill_gqa", 2, 512, 512, 8, 2, 64, 64),
    ("prefill_mqa", 2, 512, 512, 8, 1, 64, 64),
    ("decode_gqa", 8, 1, 4096, 8, 1, 64, 64),
    ("decode_mla_absorbed", 4, 1, 2048, 16, 1, 40, 32),
]
# smaller mirror set for the measured section (1 host core)
MEASURED = [
    ("prefill_gqa", 2, 256, 256, 8, 2, 64, 64),
    ("decode_gqa", 8, 1, 512, 8, 1, 64, 64),
]


def _unfused_predict(shape, hw, elem_bytes: int = 4) -> float:
    """Unfused attention on the same machine: QK^T and PV run at full Skv
    on ONE tile grid's engine (nothing shards over the mesh — the legacy
    path replicates), and the fp32 score matrix round-trips HBM four
    times (write logits, read for softmax, write probs, read for PV)."""
    from repro.sim.perf import _attn_gemm_time
    cycles = (_attn_gemm_time(shape.sq, shape.skv, shape.d, hw)
              + _attn_gemm_time(shape.sq, shape.dv, shape.skv, hw)
              + 4 * shape.sq * shape.skv)
    engine = shape.b * shape.h * cycles / hw.tile.clock_hz
    qkv_bytes = shape.b * elem_bytes * (
        shape.h * shape.sq * (shape.d + shape.dv)
        + shape.hkv * shape.skv * (shape.d + shape.dv))
    score_bytes = 4 * shape.b * shape.h * shape.sq * shape.skv * 4
    return max(engine, (qkv_bytes + score_bytes) / hw.hbm.total_bw)


def _bench_predicted() -> dict:
    from repro.core.lower import lower_attention
    from repro.core.schedule import AttnShape
    from repro.deploy import Planner
    from repro.hw.config import tpu_pod_as_accelerator

    hw = tpu_pod_as_accelerator((4, 4))
    planner = Planner(hw, elem_bytes=4)

    class _Mesh:             # lowering only reads .shape[axis]
        shape = {"data": 4, "model": 4}

    shapes = {}
    ratios = []
    for (label, b, sq, skv, h, hkv, d, dv) in SHAPES:
        shape = AttnShape(b=b, sq=sq, skv=skv, h=h, hkv=hkv, d=d, dv=dv)
        t0 = time.perf_counter()
        plan = planner.plan_cached(shape)
        resolve_us = (time.perf_counter() - t0) * 1e6
        if plan is None:
            raise RuntimeError(f"{label}: {shape.describe()} did not "
                               f"resolve to a fused plan")
        ep = lower_attention(plan.schedule, _Mesh(), "data", "model")
        if not ep.mode.startswith("flat_") or ep.degraded:
            raise RuntimeError(f"{label} lowered to {ep.describe()}, "
                               f"expected a clean flat_* mode")
        fused_s = plan.report.total_time
        unfused_s = _unfused_predict(shape, hw)
        ratio = unfused_s / fused_s
        ratios.append(ratio)
        shapes[label] = {
            "shape": shape.describe(),
            "schedule": plan.schedule.describe(),
            "mode": ep.mode,
            "plan_resolve_us": round(resolve_us, 1),
            "fused_predicted_s": fused_s,
            "unfused_predicted_s": unfused_s,
            "fused_vs_unfused": round(ratio, 3),
        }
    import math
    geomean = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
    return {"hw": hw.name, "grid": [4, 4], "shapes": shapes,
            "fused_vs_unfused_geomean": round(geomean, 3)}


def _bench_measured(reps: int) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.attention import flat_attention
    from repro.core.lower import lower_attention
    from repro.core.schedule import AttnSchedule, AttnShape
    from repro.models.attention import _sdpa

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    rng = np.random.default_rng(0)

    def best_of(fn, q, k, v):
        jax.block_until_ready(fn(q, k, v))       # compile + warm
        best = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            for _ in range(3):
                out = fn(q, k, v)
            jax.block_until_ready(out)
            best = min(best, (time.perf_counter() - t0) / 3)
        return best

    out = {}
    for (label, b, sq, skv, h, hkv, d, dv) in MEASURED:
        shape = AttnShape(b=b, sq=sq, skv=skv, h=h, hkv=hkv, d=d, dv=dv)
        sched = AttnSchedule(shape=shape, composition="merge", kv_chunk=64)
        ep = lower_attention(sched, mesh, "data", "model")
        if not ep.mode.startswith("flat_") or ep.degraded:
            raise RuntimeError(f"{label} lowered to {ep.describe()}, "
                               f"expected a clean flat_* mode")
        q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, skv, hkv, dv)), jnp.float32)
        t_unfused = best_of(
            jax.jit(lambda q, k, v: _sdpa(q, k, v, causal=True)), q, k, v)
        t_fused = best_of(
            jax.jit(lambda q, k, v, e=ep: flat_attention(
                q, k, v, mesh, e, causal=True)), q, k, v)
        out[label] = {
            "mode": ep.mode,
            "unfused_ms": round(t_unfused * 1e3, 3),
            "fused_ms": round(t_fused * 1e3, 3),
            "fused_vs_unfused": round(t_unfused / t_fused, 3),
        }
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3,
                    help="execution repetitions per shape (best-of)")
    ap.add_argument("--skip-measured", action="store_true",
                    help="skip the fake-mesh wall-time section (keep only "
                         "the deterministic cost-model comparison)")
    ap.add_argument("--out", default="BENCH_attention.json")
    args = ap.parse_args(argv)

    # must precede the first jax import (the lazy in-function imports);
    # set here, not at module top, so merely importing this module cannot
    # leak fake devices into the host process
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=16").strip()

    result = _bench_predicted()
    if not args.skip_measured:
        result["measured"] = _bench_measured(args.reps)
    result["bounds"] = {"geomean_floor": GEOMEAN_FLOOR}
    ok = result["fused_vs_unfused_geomean"] >= GEOMEAN_FLOOR
    for rec in result.get("measured", {}).values():
        ok = ok and rec["fused_vs_unfused"] > 0
    result["within_bounds"] = bool(ok)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    for label, rec in sorted(result["shapes"].items()):
        print(f"attention.predicted.{label},{rec['fused_predicted_s']*1e6:.1f},"
              f"vs_unfused={rec['fused_vs_unfused']} mode={rec['mode']}")
    for label, rec in sorted(result.get("measured", {}).items()):
        print(f"attention.exec.{label},{rec['fused_ms']*1e3:.1f},"
              f"vs_unfused={rec['fused_vs_unfused']}")
    print(f"attention.geomean,{result['fused_vs_unfused_geomean']},"
          f"within_bounds={result['within_bounds']}")
    print(f"wrote {args.out}")
    if not result["within_bounds"]:
        raise SystemExit(
            f"BENCH_attention out of bounds: geomean "
            f"{result['fused_vs_unfused_geomean']} < {GEOMEAN_FLOOR}")
    return result


def run() -> List[str]:
    """benchmarks/run.py hook: subprocess so the fake-device XLA flag never
    leaks into the shared jax runtime of the other benchmarks."""
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--reps", "1",
         "--out", os.devnull],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH":
             os.pathsep.join(filter(None, [
                 os.path.join(os.path.dirname(__file__), "..", "src"),
                 os.environ.get("PYTHONPATH", "")]))})
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-500:])
    return [l for l in proc.stdout.splitlines()
            if l.startswith("attention.")]


if __name__ == "__main__":
    main()
