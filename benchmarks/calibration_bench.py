"""Calibration benchmark -> BENCH_calibration.json (how well the SoftHier
cost model tracks this machine, and whether trusting the fitted calibration
would have picked better schedules).

Uses the shared per-mode execution machinery in `sim/calibrate.py` (the
same `MODE_CASES` table and timing discipline the routing benchmark's
efficiency harness consumes): every executable mode (summa, cannon,
splitk_summa, hierarchical, outer_systolic) runs the same GEMM grid on a
4x4 host mesh (lowering asserted clean before timing), producing the
(analytical PerfReport, measured wall time) pairs
`sim.calibrate.fit_profile` consumes. The artifact records:

- **fit quality**: the fitted `CalibrationProfile` (per-resource scale
  factors + per-superstep overhead), R^2, geomean measured/predicted ratio,
  and the `fit_ok` trust bit;
- **per-mode ratios**: measured / analytical-predicted and measured /
  calibrated-predicted per (mode, GEMM) — the dispersion of the first
  column is the mispricing calibration exists to absorb;
- **rank agreement**: how often the analytical argmin / the calibrated
  argmin matched the measured-best mode per GEMM;
- **picks**: measured-time geomean of the schedules the calibrated cost
  model picks vs the analytical picks. The calibrated ranking is only used
  when `fit_ok` (exactly like the autotuner), so this ratio is <= 1 by the
  trust-gate's construction — CI asserts it;
- **default_space**: the DEFAULT tuner dataflow set under this profile —
  both hierarchical compositions join it iff `fit_ok`.

Standalone (sets its own fake-device count; run before importing jax
elsewhere):

  PYTHONPATH=src python benchmarks/calibration_bench.py --reps 2

Also exposed to benchmarks/run.py via a subprocess `run()` so the device
count does not leak into the other benchmarks' jax runtime.
"""
import argparse
import json
import os
from typing import List


def _bench(reps: int) -> dict:
    from repro.core.autotuner import default_dataflows
    from repro.hw.config import tpu_pod_as_accelerator
    from repro.sim import calibrate as cal

    hw = tpu_pod_as_accelerator((4, 4))
    profile, samples = cal.calibrate_mesh(hw, reps=reps)

    modes: dict = {}
    for s in samples:
        rec = modes.setdefault(s.mode, {
            "predicted_s": [], "measured_s": [],
            "measured_over_predicted": [], "measured_over_calibrated": []})
        pred, calp = s.report.total_time, profile.predict(s.report)
        rec["predicted_s"].append(pred)
        rec["measured_s"].append(s.measured_s)
        rec["measured_over_predicted"].append(round(s.measured_s / pred, 3))
        rec["measured_over_calibrated"].append(
            round(s.measured_s / calp, 3) if calp > 0 else None)

    # per-GEMM picks: the analytical argmin vs the argmin of the cost the
    # tuner would actually use — BOTH computed by the same rank_stats the
    # trust gate itself uses (ranking_cost applies the fit_ok gate exactly
    # like `repro.core.autotuner.tune`), so the CI bar below cannot drift
    # from fit_profile's own picks_measured_ratio statistic
    agree_b, geo_b, shapes_n = cal.rank_stats(
        samples, lambda rep: rep.total_time)
    agree_a, geo_a, _ = cal.rank_stats(samples, cal.ranking_cost(profile))

    return {
        "mesh": list(hw.grid),
        "gemms": [list(g) for g in cal.DEFAULT_GEMM_GRID],
        "samples": len(samples),
        "fit": profile.to_dict(),
        "fit_ok": profile.fit_ok,
        "modes": modes,
        "rank_agreement": {
            "shapes": shapes_n,
            "analytical": round(agree_b, 3),
            "calibrated": round(agree_a, 3),
        },
        "picks": {
            "analytical_measured_geomean_s": geo_b,
            "calibrated_measured_geomean_s": geo_a,
            "measured_geomean_ratio": round(geo_a / geo_b, 4) if geo_b else 1.0,
        },
        "default_space": {
            "dataflows": default_dataflows(profile),
            "hierarchical_enumerated": profile.fit_ok,
        },
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=2,
                    help="execution repetitions per (mode, GEMM) (best-of)")
    ap.add_argument("--out", default="BENCH_calibration.json")
    args = ap.parse_args(argv)

    # must precede the first jax import; appended rather than set so a
    # pre-existing XLA_FLAGS keeps its settings (same pattern as
    # routing_bench — see there for why this lives in main, not module top)
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=16").strip()
    result = _bench(args.reps)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    fit = result["fit"]
    print(f"calibration.fit,{result['samples']},r2={fit['r2']:.3f} "
          f"fit_ok={result['fit_ok']} "
          f"scales=({fit['compute_scale']:.3g},{fit['dma_scale']:.3g},"
          f"{fit['noc_scale']:.3g}) step={fit['step_overhead_s']:.3g}")
    ra = result["rank_agreement"]
    print(f"calibration.rank_agreement,{ra['shapes']},"
          f"analytical={ra['analytical']} calibrated={ra['calibrated']}")
    pk = result["picks"]
    print(f"calibration.picks,{pk['calibrated_measured_geomean_s']*1e6:.1f},"
          f"ratio_vs_analytical={pk['measured_geomean_ratio']}")
    for mode, rec in sorted(result["modes"].items()):
        print(f"calibration.mode.{mode},{rec['measured_s'][0]*1e6:.1f},"
              f"meas_over_pred={rec['measured_over_predicted'][0]}")
    print(f"wrote {args.out}")
    return result


def run() -> List[str]:
    """benchmarks/run.py hook: subprocess so the fake-device XLA flag never
    leaks into the shared jax runtime of the other benchmarks."""
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--reps", "1",
         "--out", os.devnull],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH":
             os.pathsep.join(filter(None, [
                 os.path.join(os.path.dirname(__file__), "..", "src"),
                 os.environ.get("PYTHONPATH", "")]))})
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-500:])
    return [l for l in proc.stdout.splitlines()
            if l.startswith("calibration.")]


if __name__ == "__main__":
    main()
