"""End-to-end driver: train a reduced LM for a few hundred steps on this host
with checkpointing + resume, then greedy-decode a sample from it.

  PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 300
"""
import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.runtime import LoopConfig, run_training
from repro.models.model import decode_init, init_params
from repro.optim import adamw, compress
from repro.train.steps import make_serve_step, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="olmo-1b")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
args = ap.parse_args()

cfg = smoke_config(args.arch)
opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
params = init_params(jax.random.PRNGKey(0), cfg)
state0 = (params, adamw.init(params), compress.init(params))
raw = jax.jit(make_train_step(cfg, opt, microbatches=2, compress_grads=True))


def step_fn(state, batch):
    p, o, c = state
    p, o, c, m = raw(p, o, c, batch)
    return (p, o, c), m


shutil.rmtree(args.ckpt, ignore_errors=True)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch))
losses = []
state = run_training(
    step_fn, state0, data,
    LoopConfig(total_steps=args.steps, ckpt_every=100, ckpt_dir=args.ckpt),
    make_batch_arrays=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    on_metrics=lambda s, m: (
        losses.append(float(m["loss"])),
        print(f"step {s:4d} loss {float(m['loss']):.4f}")
        if s % 25 == 0 else None))
print(f"\nloss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f} "
      f"over {args.steps} steps")

# greedy-decode a continuation from the trained model
params = state[0]
serve = jax.jit(make_serve_step(cfg))
caches = decode_init(params, cfg, 1, 48)
prompt = data.batch(0)["tokens"][:1, :16]
tok = None
for i in range(16):
    logits, caches = serve(params, caches,
                           jnp.asarray(prompt[:, i:i + 1]), jnp.asarray(i))
out = []
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
for i in range(16):
    out.append(int(tok[0, 0]))
    logits, caches = serve(params, caches, tok, jnp.asarray(16 + i))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
print("prompt tokens:", prompt[0].tolist())
print("continuation :", out)
