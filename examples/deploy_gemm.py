"""Deploy-a-GEMM walkthrough: all of DiT's moving parts on one page.

1. express a workload + hardware instance,
2. enumerate schedules, inspect how the insights shape the choice,
3. lower the winner to a BSP program and look at its supersteps,
4. verify numerically (SoftHier functional model) and cross-check the same
   dataflow on a real multi-device JAX mesh (shard_map SUMMA).

  PYTHONPATH=src python examples/deploy_gemm.py
"""
import os

import numpy as np

from repro.core.autotuner import enumerate_candidates, tune
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.sim.perf import estimate
from repro.sim.softhier import run_gemm

hw = AcceleratorConfig(name="demo-8x8", grid=(8, 8),
                       tile=TileConfig(l1_bytes=2 * 1024 * 1024),
                       noc=NoCConfig(), hbm=HBMConfig(n_channels=16))

# a flat (decode-style) GEMM: M tiny, K large — Insight 4 territory
shape = GEMMShape(32, 512, 2048)
print(f"workload: {shape.m}x{shape.n}x{shape.k} flat GEMM on {hw.name}\n")

print("top candidates (insight-ordered):")
for i, cand in enumerate(enumerate_candidates(shape, hw, elem_bytes=4,
                                              max_candidates=6)):
    rep = estimate(build_program(cand, hw), hw)
    print(f"  {i}: {cand.describe():55s} -> {rep.total_time*1e6:8.1f} us")

best = tune(shape, hw, elem_bytes=4, max_candidates=24)
print(f"\nwinner: {best.schedule.describe()}")
prog = build_program(best.schedule, hw)
print(f"BSP program: {len(prog.supersteps)} supersteps, ops = {prog.op_counts()}")
print("first supersteps:")
for step in prog.supersteps[:3]:
    print(f"  [{step.label}] compute={len(step.compute)} comm={len(step.comm)}")

rng = np.random.default_rng(0)
a = rng.standard_normal((shape.m, shape.k)).astype(np.float32)
b = rng.standard_normal((shape.k, shape.n)).astype(np.float32)
c = run_gemm(prog, a, b)
err = np.abs(c - a @ b).max()
print(f"\nfunctional verification: max |err| = {err:.2e}")

print("\ncross-check: the same SUMMA dataflow as shard_map collectives "
      "(4 fake JAX devices)")
import subprocess
import sys
code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core.gemm import summa_gemm
mesh = jax.make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((32, 2048)), jnp.float32)
b = jnp.asarray(rng.standard_normal((2048, 512)), jnp.float32)
out = jax.jit(lambda x, y: summa_gemm(x, y, mesh))(a, b)
np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), rtol=1e-4, atol=1e-4)
print("  shard_map SUMMA == einsum: OK")
"""
env = dict(os.environ)
env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
env.pop("XLA_FLAGS", None)
subprocess.run([sys.executable, "-c", code], env=env, check=True)
