"""Quickstart: deploy a GEMM with DiT, inspect the schedule the autotuner
picks, verify it numerically on the SoftHier functional model, and price it
on the GH200-class instance.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.autotuner import tune
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig, softhier_gh200
from repro.sim.perf import estimate
from repro.sim.softhier import verify_gemm

# -- 1. autotune a deployment for an irregular DeepSeek-V3 projection GEMM --
hw = softhier_gh200()
shape = GEMMShape(4096, 2112, 7168)
result = tune(shape, hw, elem_bytes=1, max_candidates=24)
print(f"GEMM {shape.m}x{shape.n}x{shape.k} on {hw.name}")
print(f"  best schedule : {result.schedule.describe()}")
print(f"  predicted     : {result.report.summary(hw)}")
print(f"  candidates    : {result.candidates_tried}")

# -- 2. the same schedule machinery at toy scale, verified functionally -----
mini = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
sched = Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=32), "summa")
prog = build_program(sched, mini)
rng = np.random.default_rng(0)
a = rng.standard_normal((64, 128)).astype(np.float32)
b = rng.standard_normal((128, 64)).astype(np.float32)
verify_gemm(prog, a, b)    # raises if the BSP program's C != A @ B
print(f"\nfunctional check on mini 4x4 instance: OK "
      f"({len(prog.supersteps)} BSP supersteps, "
      f"{prog.op_counts()['multicast']} hardware multicasts)")
print(f"  cost model    : {estimate(prog, mini).summary(mini)}")
