"""Plan-routed model matmuls (models/matmul.pmm + shard_ctx.GemmContext).

Covers the PR-2 contracts:
- with no gemm context, pmm is exactly `x @ w` and every block kind's
  forward is bit-for-bit unchanged (recording must not perturb numerics);
- the tied-embedding logits refactor (einsum -> x @ embed.T) is exact;
- dit_gemm derives the planner GEMMShape from flattened leading dims
  (regression: batched operands used to read a.shape[0]/b.shape[1] raw);
- model_workload is cross-validated against the (tag, GEMMShape) pairs the
  model actually traces — exact coverage for gqa/MLA/MoE/mamba2/xlstm/vlm
  and the encoder-decoder stack (seamless, incl. cross-attention K/V);
- a serve-style installed context routes matmuls through dit_gemm with
  plan hits for the model's workload shapes (multidevice, subprocess).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.schedule import GEMMShape
from repro.deploy import (Planner, model_workload, moe_dispatch_geometry,
                          workload_coverage)
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.models import shard_ctx
from repro.models.matmul import pmm
from repro.models.model import forward, init_params
from repro.models.shard_ctx import GemmContext

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))

# one smoke arch per block kind the satellite names (vlm joined when
# model_workload learned the modality-frontend projection; encdec joined
# when it learned the encoder blocks + cross-attention K/V projections)
BLOCK_KINDS = {
    "gqa": "gemma-2b",
    "mla": "deepseek-v2-236b",
    "moe": "deepseek-moe-16b",
    "mamba2": "zamba2-1.2b",
    "xlstm": "xlstm-1.3b",
    "vlm": "phi-3-vision-4.2b",
    "encdec": "seamless-m4t-medium",
}


def _stub_embeds(cfg, batch: int, key: str, abstract: bool):
    shape = (batch, cfg.n_prefix, cfg.d_model)
    if abstract:
        return {key: jax.ShapeDtypeStruct(shape, jnp.bfloat16)}
    rng = np.random.default_rng(9)
    return {key: jnp.asarray(rng.standard_normal(shape), jnp.bfloat16)}


def _prefill_kwargs(cfg, batch: int, abstract: bool = True):
    """Extra forward() inputs a modality-frontend arch needs (the VLM stub's
    precomputed patch embeddings / the enc-dec stub's frame embeddings)."""
    if getattr(cfg, "frontend", "none") == "vision_stub":
        return _stub_embeds(cfg, batch, "prefix_embeds", abstract)
    if getattr(cfg, "is_encoder_decoder", False):
        return _stub_embeds(cfg, batch, "encoder_embeds", abstract)
    return {}


def _decode_kwargs(cfg, batch: int):
    """Extra decode_step() inputs: enc-dec archs attend to the precomputed
    encoder output every step (cross-attention K/V re-project it)."""
    if getattr(cfg, "is_encoder_decoder", False):
        return _stub_embeds(cfg, batch, "encoder_out", abstract=True)
    return {}


# ---------------------------------------------------------------------------
# pmm fallback contract
# ---------------------------------------------------------------------------

def test_pmm_no_context_is_plain_matmul():
    rng = np.random.default_rng(0)
    for shape, dtype in (((6, 16), jnp.float32), ((2, 5, 16), jnp.bfloat16),
                         ((2, 3, 4, 16), jnp.bfloat16)):
        x = jnp.asarray(rng.standard_normal(shape), dtype)
        w = jnp.asarray(rng.standard_normal((16, 8)), dtype)
        assert shard_ctx.get_gemm_context() is None
        assert jnp.array_equal(pmm(x, w, tag="t"), x @ w)


def test_pmm_record_only_context_is_bitwise_transparent():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 5, 16)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.bfloat16)
    base = x @ w
    ctx = GemmContext(mesh=None)
    with shard_ctx.gemm_context(ctx):
        out = pmm(x, w, tag="probe")
    assert jnp.array_equal(out, base)
    assert ctx.stats.unrouted == 1
    assert ("probe", GEMMShape(10, 8, 16)) in ctx.stats.observed


def test_tied_head_matmul_matches_prerefactor_einsum():
    """The lm-head refactor: einsum('bsd,vd->bsv') became x @ embed.T."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 7, 32)), jnp.bfloat16)
    embed = jnp.asarray(rng.standard_normal((64, 32)), jnp.bfloat16)
    old = jnp.einsum("bsd,vd->bsv", x, embed)
    new = pmm(x, embed.T, tag="lm_head")
    assert jnp.array_equal(old, new)


# ---------------------------------------------------------------------------
# per-block-kind forward parity (no mesh)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(BLOCK_KINDS))
def test_forward_parity_no_mesh(kind):
    """pmm-routed forward == the x @ w baseline bit-for-bit with no mesh:
    the no-context path and the record-only path must agree exactly (the
    fallback is literally `x @ w`, and recording is trace-time only)."""
    cfg = smoke_config(BLOCK_KINDS[kind])
    rng = np.random.default_rng(3)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    kwargs = _prefill_kwargs(cfg, 2, abstract=False)
    base = forward(params, toks, cfg, **kwargs)
    ctx = GemmContext(mesh=None)
    with shard_ctx.gemm_context(ctx):
        recorded = forward(params, toks, cfg, **kwargs)
    assert jnp.array_equal(base, recorded)
    assert ctx.stats.observed, "forward traced no pmm calls"


# ---------------------------------------------------------------------------
# dit_gemm batched-operand regression
# ---------------------------------------------------------------------------

def test_dit_gemm_batched_planner_shape_regression():
    """The planner path used to build GEMMShape(a.shape[0], b.shape[1],
    a.shape[1]) — wrong (and shard_map-fatal) for batched operands. Leading
    dims must flatten into M for both the lookup and the dispatch."""
    from repro.core.gemm import dit_gemm
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    planner = Planner(MINI, elem_bytes=4, max_candidates=8)
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    out = dit_gemm(a, b, mesh, planner=planner)
    assert out.shape == (2, 8, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b),
                               rtol=1e-5, atol=1e-5)
    # the planner was consulted with the flattened problem, not (2, 16, 8)
    assert planner.cache.contains(GEMMShape(16, 16, 32), 4, MINI)
    assert not planner.cache.contains(GEMMShape(2, 16, 8), 4, MINI)


def test_dit_gemm_batched_plan_dispatch():
    """A tuned plan dispatches batched operands through its dataflow."""
    from repro.core.gemm import dit_gemm
    from repro.core.schedule import Schedule, Tiling
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.standard_normal((4, 8, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    sched = Schedule(GEMMShape(32, 16, 32), Tiling(4, 4, 1, tk=8), "summa")
    out = jax.jit(lambda x, y: dit_gemm(x, y, mesh, plan=sched))(a, b)
    assert out.shape == (4, 8, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_dit_gemm_modes_differentiable():
    """Routed training backprops through the collective loops: every mode's
    scan-based panel/skew/rotate loop must have a reverse-mode path."""
    from repro.core.gemm import dit_gemm
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    ones = jnp.ones((8, 8), jnp.float32)
    for mode in ("auto", "summa", "cannon", "splitk", "allgather"):
        ga, gb = jax.grad(
            lambda x, y, m=mode: dit_gemm(x, y, mesh, mode=m).sum(),
            argnums=(0, 1))(a, b)
        np.testing.assert_allclose(np.asarray(ga), np.asarray(ones @ b.T),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(a.T @ ones),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model_workload cross-validation against the recorded workload
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(BLOCK_KINDS))
def test_model_workload_cross_validation(kind):
    """model_workload must describe exactly the GEMMs the model runs: every
    predicted shape is observed and every observed shape predicted — at
    100% coverage for every block kind, including the encoder-decoder
    stack (encoder blocks + decoder cross-attention K/V projections)."""
    cfg = smoke_config(BLOCK_KINDS[kind])
    b, s = 2, 16
    kwargs = _prefill_kwargs(cfg, b)
    ctx = GemmContext(mesh=None)
    with shard_ctx.gemm_context(ctx):
        pshapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
        jax.eval_shape(lambda p, t, **kws: forward(p, t, cfg, **kws),
                       pshapes, toks, **kwargs)
    observed = ctx.stats.observed_shapes()
    predicted = model_workload(cfg, b, s, kind="prefill")
    cov = workload_coverage(predicted, observed)
    assert cov["covered"] == 1.0, f"unpredicted shapes: {cov['extra']}"
    assert cov["missing"] == [], f"never-executed shapes: {cov['missing']}"


@pytest.mark.parametrize("kind", sorted(BLOCK_KINDS))
def test_model_workload_cross_validation_decode(kind):
    """Decode kind must match the decode path — including MLA's absorbed
    form (q-absorb / v-un-absorb contractions instead of K/V up-projection)
    and the recurrent SSM/xLSTM mixers."""
    from repro.models.model import decode_init, decode_step
    cfg = smoke_config(BLOCK_KINDS[kind])
    b, max_len = 2, 16
    kwargs = _decode_kwargs(cfg, b)
    ctx = GemmContext(mesh=None)
    with shard_ctx.gemm_context(ctx):
        pshapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        caches = jax.eval_shape(
            lambda: decode_init({}, cfg, b, max_len))
        toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        jax.eval_shape(lambda p, c, t, i, **kws: decode_step(
                           p, c, t, i, cfg, encoder_out=kws.get("encoder_out")),
                       pshapes, caches, toks, pos, **kwargs)
    observed = ctx.stats.observed_shapes()
    predicted = model_workload(cfg, b, max_len, kind="decode")
    cov = workload_coverage(predicted, observed)
    assert cov["covered"] == 1.0, f"unpredicted shapes: {cov['extra']}"
    assert cov["missing"] == [], f"never-executed shapes: {cov['missing']}"


def test_moe_geometry_prediction_matches_model():
    """moe_dispatch_geometry (deploy, jax-free) must stay in sync with the
    dispatch-group/capacity logic moe.apply_moe actually uses — the expert
    GEMM shapes it records are the check."""
    cfg = smoke_config("deepseek-moe-16b")
    b, s = 2, 16
    _, cap = moe_dispatch_geometry(b * s, cfg)
    ctx = GemmContext(mesh=None)
    with shard_ctx.gemm_context(ctx):
        pshapes = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
        jax.eval_shape(lambda p, t: forward(p, t, cfg), pshapes, toks)
    expert_shapes = {shape for (tag, shape) in ctx.stats.observed
                     if tag.startswith("moe.expert")}
    assert expert_shapes == {GEMMShape(cap, cfg.moe_d_ff, cfg.d_model),
                             GEMMShape(cap, cfg.d_model, cfg.moe_d_ff)}


# ---------------------------------------------------------------------------
# routed dispatch: single-device end to end, multidevice in a subprocess
# ---------------------------------------------------------------------------

def test_routed_forward_matches_baseline_single_device():
    """Warm planner + live mesh: forward routes through dit_gemm with a
    100% resolve rate and matches the unrouted numerics."""
    cfg = smoke_config("gemma-2b")
    rng = np.random.default_rng(7)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    base = forward(params, toks, cfg)

    planner = Planner(MINI, elem_bytes=4, max_candidates=8)
    planner.batch_tune(model_workload(cfg, 2, 16, kind="prefill"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = GemmContext(mesh=mesh, planner=planner)
    with shard_ctx.gemm_context(ctx):
        routed = jax.jit(lambda p, t: forward(p, t, cfg))(params, toks)
    assert ctx.stats.routed > 0 and ctx.stats.fallback == 0
    assert ctx.stats.resolve_rate == 1.0
    np.testing.assert_allclose(np.asarray(routed, np.float32),
                               np.asarray(base, np.float32),
                               rtol=2e-2, atol=2e-2)


SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MULTIDEVICE_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import smoke_config
    from repro.deploy import Planner, model_workload
    from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                                 TileConfig)
    from repro.models import shard_ctx
    from repro.models.model import forward, init_params
    from repro.models.shard_ctx import GemmContext

    MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                             tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                             noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
    cfg = smoke_config("gemma-2b")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    base = np.asarray(forward(params, toks, cfg), np.float32)

    # serve-style: warm the planner for the model workload, install the
    # context, trace on a 2x2 mesh
    planner = Planner(MINI, elem_bytes=4, max_candidates=8)
    planner.batch_tune(model_workload(cfg, 4, 16, kind="prefill"))
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ctx = GemmContext(mesh=mesh, planner=planner)
    shard_ctx.set_gemm_context(ctx)
    routed = np.asarray(
        jax.jit(lambda p, t: forward(p, t, cfg))(params, toks), np.float32)
    shard_ctx.set_gemm_context(None)

    s = ctx.stats
    assert s.routed > 0, "nothing routed"
    assert s.fallback == 0, f"plan misses: {s.describe()}"
    assert s.resolve_rate == 1.0, s.describe()
    # every workload shape the model traced resolved from the warmed cache
    for shape in s.observed_shapes():
        assert planner.plan_cached(shape) is not None, shape
    np.testing.assert_allclose(routed, base, rtol=5e-2, atol=5e-2)
    print("stats:", s.describe())
    print("ALL_OK")
""")


@pytest.mark.slow
def test_serve_context_plan_hits_multidevice():
    """Serve-installed planner context yields plan hits for the model's
    workload shapes on a real multi-device mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", MULTIDEVICE_BODY], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout


ONLINE_TUNE_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import smoke_config
    from repro.deploy import Planner
    from repro.deploy.bucketing import BucketingPolicy
    from repro.deploy.plan import SOURCE_ANALYTIC, SOURCE_TUNED
    from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                                 TileConfig)
    from repro.models import shard_ctx
    from repro.models.model import forward, init_params
    from repro.models.shard_ctx import GemmContext

    MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                             tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                             noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
    cfg = smoke_config("gemma-2b")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    base = np.asarray(forward(params, toks, cfg), np.float32)

    # COLD planner: nothing warmed, transfers disabled — every traced shape
    # is absent from the cache and must resolve through the online
    # (analytic) tuning path, never the auto-dataflow fallback
    planner = Planner(MINI, elem_bytes=4, max_candidates=8,
                      policy=BucketingPolicy(max_transfers=0))
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ctx = GemmContext(mesh=mesh, planner=planner)
    shard_ctx.set_gemm_context(ctx)
    routed = np.asarray(
        jax.jit(lambda p, t: forward(p, t, cfg))(params, toks), np.float32)
    shard_ctx.set_gemm_context(None)

    s = ctx.stats
    assert s.analytic > 0, "nothing resolved via the analytic variant"
    assert s.hits == 0 and s.bucketed == 0, s.describe()
    assert s.fallback == 0, f"silent degrade to auto: {s.describe()}"
    assert s.silent_degrades == 0, s.describe()
    assert s.resolve_rate == 1.0, s.describe()
    # every online-served shape is cached with `analytic` provenance
    pend = planner.pending_refinements
    assert pend, "online tunes queued nothing for refinement"
    for shape in s.observed_shapes():
        p = planner.cache.peek(shape, 4, MINI, planner.variant)
        assert p is not None and p.source == SOURCE_ANALYTIC, (shape, p)
    # background refinement full-tunes each and upgrades the provenance
    planner.refine_pending()
    for shape in pend:
        p = planner.cache.peek(shape, 4, MINI, planner.variant)
        assert p.source == SOURCE_TUNED, (shape, p.source)
    np.testing.assert_allclose(routed, base, rtol=5e-2, atol=5e-2)
    print("stats:", s.describe())
    print("ALL_OK")
""")


@pytest.mark.slow
def test_cold_serve_online_tunes_multidevice():
    """A routed multidevice trace with a COLD planner resolves every shape
    via the `analytic` online-tuning variant (recorded provenance, zero
    fallbacks/silent degrades) and background refinement upgrades each
    entry to `tuned`."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", ONLINE_TUNE_BODY], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout
