"""Cluster index remap (paper §3.1.2): logical-grid collectives lower to
single physical mask groups."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(requirements-dev.txt)")
from hypothesis import given
from hypothesis import strategies as st

from repro.core.remap import ClusterRemap, candidate_remaps, flat_mask_group

POW2 = [1, 2, 4, 8, 16]


@st.composite
def remaps(draw):
    pr = draw(st.sampled_from([2, 4, 8]))
    pc = draw(st.sampled_from([2, 4, 8]))
    n = pr * pc
    lr = draw(st.sampled_from([d for d in POW2 + [32, 64] if d <= n and n % d == 0]))
    return ClusterRemap((pr, pc), (lr, n // lr))


@given(remaps())
def test_roundtrip(rm):
    for pi in range(rm.physical[0]):
        for pj in range(rm.physical[1]):
            lr, lc = rm.to_logical(pi, pj)
            assert rm.to_physical(lr, lc) == (pi, pj)


@given(remaps())
def test_logical_row_group_is_one_mask_group(rm):
    for lr in range(rm.logical[0]):
        group = rm.logical_row_group(lr)
        members = group.members(rm.physical)
        expect = sorted(rm.to_physical(lr, lc) for lc in range(rm.logical[1]))
        assert sorted(members) == expect


@given(remaps())
def test_logical_col_group_is_one_mask_group(rm):
    for lc in range(rm.logical[1]):
        group = rm.logical_col_group(lc)
        expect = sorted(rm.to_physical(lr, lc) for lr in range(rm.logical[0]))
        assert sorted(group.members(rm.physical)) == expect


def test_logical_rect_group():
    rm = ClusterRemap((4, 4), (2, 8))
    g = rm.logical_rect_group(0, 4, 2, 4)
    expect = sorted(rm.to_physical(lr, lc) for lr in range(2) for lc in range(4, 8))
    assert sorted(g.members(rm.physical)) == expect


def test_paper_insight4_remap():
    """32x32 physical -> 1x1024 logical (the flat-GEMM remap of §4.1.3)."""
    rm = ClusterRemap((32, 32), (1, 1024))
    g = rm.logical_row_group(0)
    assert len(g.members(rm.physical)) == 1024


def test_mismatched_sizes_rejected():
    with pytest.raises(ValueError):
        ClusterRemap((4, 4), (2, 4))
    with pytest.raises(ValueError):
        ClusterRemap((4, 3), (2, 6))


def test_candidate_remaps_enumeration():
    cands = candidate_remaps((4, 4))
    assert [c.logical for c in cands] == [(1, 16), (2, 8), (4, 4), (8, 2), (16, 1)]


def test_flat_mask_group():
    # flat index L on a 4x4 grid; group {L : L % 4 == 1} = column 1
    g = flat_mask_group(1, 3, (4, 4))
    assert sorted(g.members((4, 4))) == [(i, 1) for i in range(4)]
