"""HBM data layout (paper §3.2): split/placement schemes, preload packing."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.layout import (DataLayout, PlacementScheme, SplitScheme,
                               base_layout, candidate_layouts, optimal_layout,
                               pack_preload, unpack_preload)


def test_split_scheme_block_shape():
    s = SplitScheme(4, 4)
    assert s.block_shape((64, 128)) == (16, 32)
    with pytest.raises(ValueError):
        s.block_shape((65, 128))


def test_base_layout_single_channel():
    lay = base_layout((64, 64), 16, 16, n_channels=8)
    # every tile lands on channel 0: the paper's non-distributed base layout
    for ti in range(4):
        for tj in range(4):
            assert lay.channel_of_tile(ti, tj, (64, 64)) == 0


def test_optimal_layout_spreads_channels():
    lay = optimal_layout((64, 64), 16, 16, n_channels=8)
    chans = {lay.channel_of_tile(ti, tj, (64, 64))
             for ti in range(4) for tj in range(4)}
    assert len(chans) == 8  # 16 tile-blocks round-robin over 8 channels


def test_channel_traffic_histogram():
    lay = optimal_layout((64, 64), 16, 16, n_channels=4)
    reads = [(ti, tj) for ti in range(4) for tj in range(4)]
    traffic = lay.channel_traffic(reads, (64, 64), elem_bytes=4)
    assert sum(traffic.values()) == 64 * 64 * 4
    assert max(traffic.values()) == min(traffic.values())  # perfectly balanced


@given(gm=st.sampled_from([1, 2, 4]), gn=st.sampled_from([1, 2, 4]),
       tm=st.sampled_from([4, 8]), tn=st.sampled_from([4, 8]),
       nch=st.sampled_from([1, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(gm, gn, tm, tn, nch):
    m, n = gm * tm * 2, gn * tn * 2
    lay = DataLayout(SplitScheme(gm, gn), PlacementScheme(tm, tn), nch)
    mat = np.arange(m * n, dtype=np.float32).reshape(m, n)
    images = pack_preload(mat, lay, elem_bytes=4)
    out = unpack_preload(images, lay, (m, n), np.float32)
    np.testing.assert_array_equal(mat, out)


def test_tile_addresses_unique():
    lay = DataLayout(SplitScheme(2, 2), PlacementScheme(8, 8), n_channels=4)
    seen = set()
    for ti in range(4):
        for tj in range(4):
            addr = lay.tile_address(ti, tj, (32, 32), 4)
            assert addr not in seen
            seen.add(addr)


def test_candidate_layouts_include_base_and_optimal():
    cands = candidate_layouts((64, 64), 16, 16, n_channels=8)
    grids = {(c.split.grid_m, c.split.grid_n) for c in cands}
    assert (1, 1) in grids and (4, 4) in grids
