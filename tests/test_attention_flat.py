"""FlatAttention (the fused attention dataflow) + the attention-path
bugfix sweep that rode along with it.

Covers the PR-10 contracts:
- `flat_attention` (merge and ring compositions) matches the `_sdpa`
  oracle — forward AND grads — across GQA/MQA, non-causal, dv != d, and
  decode (q_positions + kv_len) geometries;
- `lower_attention` resolves every fallback chain with a machine-readable
  reason and never lands on the silent `auto` mode (device-free);
- the planner resolves AttnShapes like GEMMs: closed-form candidates,
  tuned/analytic sources, serialization + cache round-trips, and
  `shapes_for` never offers an attention plan as a bucketing seed;
- satellite regressions: chunked_sdpa's prime-length tail (pad + mask,
  not a divisor walk), the decode branch threading the caller's `causal`
  flag, pmm recording non-routable operands before bailing, and MLA's
  absorbed-form per-head accounting (count = n_heads);
- a routed multidevice proof (subprocess, slow): gemma-2b (GQA) and
  deepseek-v2 (MLA) decode through the fused mode with resolve rate 1.0
  and zero silent degrades.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import lower
from repro.core.attention import attn_candidates, attn_tune, flat_attention
from repro.core.lower import lower_attention
from repro.core.schedule import (ATTN_DATAFLOW, AttnSchedule, AttnShape,
                                 GEMMShape)
from repro.deploy import (PlanCache, Planner, model_workload,
                          schedule_from_dict, schedule_to_dict)
from repro.deploy.plan import SOURCE_ANALYTIC, SOURCE_TUNED
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.models import shard_ctx
from repro.models.attention import (_chunk, _sdpa, chunked_sdpa,
                                    gqa_attention, gqa_params, mla_attention,
                                    mla_params)
from repro.models.matmul import pattn, pmm
from repro.models.shard_ctx import GemmContext, GemmStats

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))


class FakeMesh:            # lowering only reads .shape[axis]
    shape = {"data": 4, "model": 4}


def _shape(b=2, sq=64, skv=64, h=8, hkv=4, d=16, dv=16, causal=True):
    return AttnShape(b=b, sq=sq, skv=skv, h=h, hkv=hkv, d=d, dv=dv,
                     causal=causal)


def _sched(shape, comp="merge", kv_chunk=16):
    return AttnSchedule(shape=shape, composition=comp, kv_chunk=kv_chunk)


def _qkv(rng, b, sq, skv, h, hkv, d, dv, dtype=jnp.float32):
    return (jnp.asarray(rng.standard_normal((b, sq, h, d)), dtype),
            jnp.asarray(rng.standard_normal((b, skv, hkv, d)), dtype),
            jnp.asarray(rng.standard_normal((b, skv, hkv, dv)), dtype))


# -- lowering: the fallback matrix is machine-readable, never silent ---------

def test_lower_merge_clean():
    ep = lower_attention(_sched(_shape()), FakeMesh(), "data", "model")
    assert ep.mode == "flat_merge" and not ep.reasons()
    assert ep.kwargs["composition"] == "merge"
    assert ep.kwargs["head_shard"] is True      # h=8, hkv=4 both divide dn=4


def test_lower_ring_clean():
    ep = lower_attention(_sched(_shape(), comp="ring"), FakeMesh(),
                         "data", "model")
    assert ep.mode == "flat_ring" and not ep.reasons()


def test_lower_ring_seq_indivisible_demotes_to_merge():
    ep = lower_attention(_sched(_shape(sq=63), comp="ring"), FakeMesh(),
                         "data", "model")
    assert ep.mode == "flat_merge"
    assert lower.ATTN_SEQ_NOT_DIVISIBLE in ep.reasons()
    assert ep.kwargs["composition"] == "merge"


def test_lower_kv_indivisible_demotes_to_unfused():
    ep = lower_attention(_sched(_shape(skv=63)), FakeMesh(), "data", "model")
    assert ep.mode == "unfused_attn"
    assert lower.ATTN_KV_NOT_DIVISIBLE in ep.reasons()
    assert ep.kwargs == {}


def test_lower_heads_replicated_is_kwarg_demotion():
    # hkv=2 neither divides dn=4 nor is 1 -> replicate heads, mode unchanged
    ep = lower_attention(_sched(_shape(hkv=2)), FakeMesh(), "data", "model")
    assert ep.mode == "flat_merge"
    assert lower.ATTN_HEADS_REPLICATED in ep.reasons()
    assert ep.kwargs["head_shard"] is False


def test_lower_mqa_heads_shard():
    # hkv=1 is fully replicable, so query heads still shard
    ep = lower_attention(_sched(_shape(hkv=1)), FakeMesh(), "data", "model")
    assert ep.mode == "flat_merge" and not ep.reasons()
    assert ep.kwargs["head_shard"] is True


def test_lower_unknown_composition():
    import types
    sched = types.SimpleNamespace(composition="zigzag", kv_chunk=16,
                                  shape=_shape())
    ep = lower_attention(sched, FakeMesh(), "data", "model")
    assert ep.mode == "flat_merge"
    assert lower.ATTN_UNKNOWN_COMPOSITION in ep.reasons()


@pytest.mark.parametrize("kwargs", [
    {}, {"sq": 63}, {"skv": 63}, {"hkv": 2},
])
def test_lower_attention_never_lands_on_auto(kwargs):
    """The degrade target is the named unfused path, never silent auto."""
    for comp in ("merge", "ring"):
        ep = lower_attention(_sched(_shape(**kwargs), comp=comp),
                             FakeMesh(), "data", "model")
        assert not ep.degraded
        assert ep.mode in ("flat_merge", "flat_ring", "unfused_attn")


def test_attention_vocabulary_registered():
    """Modes and reasons live in the pinned registries (test_docs pins the
    registries into docs/dataflows.md, so this transitively pins the doc)."""
    for mode in ("flat_merge", "flat_ring", "unfused_attn"):
        assert mode in lower.EXEC_MODES
    for reason in (lower.ATTN_SEQ_NOT_DIVISIBLE, lower.ATTN_KV_NOT_DIVISIBLE,
                   lower.ATTN_HEADS_REPLICATED,
                   lower.ATTN_UNKNOWN_COMPOSITION):
        assert reason in lower.REASONS


# -- candidates + tuning ------------------------------------------------------

def test_attn_candidates_legality():
    # skv must shard over the row axis
    assert attn_candidates(_shape(skv=63), MINI) == ()
    # decode (sq=1) gets merge only; prefill with divisible sq adds ring
    decode = attn_candidates(_shape(sq=1, skv=4096, hkv=1), MINI)
    assert decode and all(c.composition == "merge" for c in decode)
    prefill = attn_candidates(_shape(sq=256, skv=256), MINI)
    assert {c.composition for c in prefill} == {"merge", "ring"}
    for c in prefill:
        assert c.shape == _shape(sq=256, skv=256)


def test_attn_tune_prices_and_picks():
    shape = _shape(sq=256, skv=256)
    res = attn_tune(shape, MINI)
    assert res.schedule in attn_candidates(shape, MINI)
    assert res.report.total_time > 0
    assert res.candidates_tried == len(attn_candidates(shape, MINI))
    with pytest.raises(RuntimeError):
        attn_tune(_shape(skv=63), MINI)


# -- planner + cache: attention shapes resolve like GEMMs --------------------

def test_attn_schedule_serialization_roundtrip():
    sched = attn_tune(_shape(), MINI).schedule
    d = schedule_to_dict(sched)
    assert d["kind"] == "attention"
    assert schedule_from_dict(d) == sched


def test_planner_attention_sources_and_cache(tmp_path):
    cache = PlanCache(str(tmp_path))
    planner = Planner(MINI, cache=cache)
    shape = _shape()

    # cold dispatch path: online analytic pricing
    analytic = planner.plan_cached(shape)
    assert analytic is not None and analytic.source == SOURCE_ANALYTIC
    assert analytic.schedule.shape == shape
    assert analytic.schedule.dataflow == ATTN_DATAFLOW

    # warm-up path upgrades to tuned; re-lookup serves the cached entry
    tuned = planner.plan(shape)
    assert tuned.source == SOURCE_TUNED
    assert planner.plan_cached(shape).source == SOURCE_TUNED

    # attention plans persist but never seed GEMM bucketing transfers
    planner.plan(GEMMShape(256, 256, 256))
    assert list(cache.shapes_for(planner.elem_bytes, MINI,
                                 planner.variant)) == \
        [GEMMShape(256, 256, 256)]

    # a fresh planner over the same directory reloads the attention plan
    again = Planner(MINI, cache=PlanCache(str(tmp_path)))
    assert again.plan_cached(shape).schedule == tuned.schedule

    # attention shapes are never queued for background refinement
    assert shape not in planner.pending_refinements


def test_gemm_stats_attention_roundtrip():
    stats = GemmStats()
    stats.record_attn("attn.sdpa", _shape())
    stats.record_attn("attn.sdpa", _shape())
    stats.record_attn("mla.decode", _shape(sq=1, hkv=1, causal=True))
    stats.record("attn.q", GEMMShape(64, 64, 64))
    stats.unroutable += 1
    d = stats.to_dict()
    assert d["unroutable"] == 1
    rt = GemmStats.from_dict(d)
    assert rt.to_dict() == d
    assert rt.attn_observed[("attn.sdpa", _shape())] == 2
    # attention shapes never leak into the GEMM-observed workload (its
    # consumers sort on (m, n, k) and rebuild GEMMShape(*shape))
    assert stats.observed_shapes() == [GEMMShape(64, 64, 64)]


def test_pattn_plan_miss_degrades_to_named_unfused():
    """No planner -> counted fallback, the caller's unfused closure runs."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = GemmContext(mesh=mesh, planner=None)
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 8, 8, 4, 2, 16, 16)
    ran = []
    with shard_ctx.gemm_context(ctx):
        out = pattn(q, k, v, causal=True, tag="attn.sdpa",
                    unfused=lambda: ran.append(1) or _sdpa(q, k, v,
                                                           causal=True))
    assert ran == [1]
    assert ctx.stats.fallback == 1 and ctx.stats.resolve_rate == 0.0
    np.testing.assert_array_equal(out, _sdpa(q, k, v, causal=True))


# -- fused executor vs the _sdpa oracle (single device; multidevice parity
#    runs in the subprocess proof below) ------------------------------------

@pytest.mark.parametrize("case", [
    dict(h=8, hkv=2, causal=True),                    # GQA
    dict(h=8, hkv=1, dv=24, causal=True),             # MQA, dv != d
    dict(h=4, hkv=4, causal=False),                   # MHA, non-causal
])
def test_flat_attention_matches_sdpa(case):
    causal = case.pop("causal")
    rng = np.random.default_rng(3)
    shape = _shape(causal=causal, **case)
    q, k, v = _qkv(rng, shape.b, shape.sq, shape.skv, shape.h, shape.hkv,
                   shape.d, shape.dv)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ep = lower_attention(_sched(shape), mesh, "data", "model")
    assert ep.mode == "flat_merge"
    got = flat_attention(q, k, v, mesh, ep, causal=causal)
    ref = _sdpa(q, k, v, causal=causal)
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)


def test_flat_attention_decode_positions_and_kv_len():
    rng = np.random.default_rng(4)
    shape = _shape(b=2, sq=1, skv=16, h=8, hkv=2)
    q, k, v = _qkv(rng, 2, 1, 16, 8, 2, 16, 16)
    qpos = jnp.array([5], jnp.int32)
    klen = jnp.array([6, 9], jnp.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ep = lower_attention(_sched(shape), mesh, "data", "model")
    got = flat_attention(q, k, v, mesh, ep, causal=True, q_positions=qpos,
                         kv_len=klen)
    ref = _sdpa(q, k, v, causal=True, q_positions=qpos, kv_len=klen)
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)


def test_flat_attention_grads_match_sdpa():
    rng = np.random.default_rng(5)
    shape = _shape(h=8, hkv=2)
    q, k, v = _qkv(rng, shape.b, shape.sq, shape.skv, shape.h, shape.hkv,
                   shape.d, shape.dv)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ep = lower_attention(_sched(shape), mesh, "data", "model")
    g_ref = jax.grad(lambda q, k, v: _sdpa(q, k, v, causal=True).sum(),
                     argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(
        lambda q, k, v: flat_attention(q, k, v, mesh, ep,
                                       causal=True).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for ref, got in zip(g_ref, g_got):
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)


# -- satellite 1: chunked_sdpa prime-length tail (pad + mask) ----------------

def test_chunk_is_a_clamp_not_a_divisor_walk():
    # the old fit() walked divisors down: _chunk(997, 256) returned 1 and
    # the flash path degenerated to one column per step
    assert _chunk(997, 256) == 256
    assert _chunk(97, 32) == 32
    assert _chunk(5, 256) == 5


@pytest.mark.parametrize("causal", [True, False])
def test_chunked_sdpa_prime_seq_parity(causal):
    rng = np.random.default_rng(6)
    q, k, v = _qkv(rng, 2, 97, 97, 4, 2, 16, 16)
    got = chunked_sdpa(q, k, v, causal=causal, chunk_q=32, chunk_k=32)
    ref = _sdpa(q, k, v, causal=causal)
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)


def test_chunked_sdpa_prime_seq_grads():
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 1, 97, 97, 2, 2, 8, 8)
    g_ref = jax.grad(lambda q, k, v: _sdpa(q, k, v, causal=True).sum(),
                     argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(
        lambda q, k, v: chunked_sdpa(q, k, v, causal=True, chunk_q=32,
                                     chunk_k=32).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for ref, got in zip(g_ref, g_got):
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)


def test_chunked_sdpa_ragged_kv_only():
    rng = np.random.default_rng(8)
    q, k, v = _qkv(rng, 2, 64, 97, 4, 1, 16, 16)
    got = chunked_sdpa(q, k, v, causal=False, chunk_q=32, chunk_k=32)
    ref = _sdpa(q, k, v, causal=False)
    np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)


# -- satellite 2: the decode branch threads the caller's causal flag ---------

def _gqa_decode_fixture():
    cfg = smoke_config("gemma-2b")
    rng = np.random.default_rng(9)
    p = gqa_params(jax.random.PRNGKey(0), cfg)
    cache = {
        "k": jnp.asarray(rng.standard_normal(
            (1, 16, cfg.n_kv_heads, cfg.hd)), cfg.dtype),
        "v": jnp.asarray(rng.standard_normal(
            (1, 16, cfg.n_kv_heads, cfg.hd)), cfg.dtype),
        "index": jnp.asarray(8, jnp.int32),
    }
    x = jnp.asarray(rng.standard_normal((1, 1, cfg.d_model)), cfg.dtype)
    return cfg, p, cache, x


def test_decode_causal_flag_is_threaded():
    """Scoring a query at a position EARLIER than the cache frontier must
    see different attention under causal=True (keys beyond the position
    masked) vs causal=False (whole valid prefix visible). The old branch
    hard-coded causal=True, making the two bitwise identical."""
    cfg, p, cache, x = _gqa_decode_fixture()
    positions = jnp.array([3], jnp.int32)       # < cache index 8
    out_c, _ = gqa_attention(p, x, cfg, positions, cache=dict(cache),
                             causal=True)
    out_nc, _ = gqa_attention(p, x, cfg, positions, cache=dict(cache),
                              causal=False)
    assert np.isfinite(np.asarray(out_c, np.float32)).all()
    assert np.isfinite(np.asarray(out_nc, np.float32)).all()
    assert not np.allclose(np.asarray(out_c, np.float32),
                           np.asarray(out_nc, np.float32))


def test_cache_and_kv_input_are_mutually_exclusive():
    cfg, p, cache, x = _gqa_decode_fixture()
    enc = jnp.zeros((1, 4, cfg.d_model), cfg.dtype)
    with pytest.raises(ValueError, match="mutually"):
        gqa_attention(p, x, cfg, jnp.array([8], jnp.int32), cache=cache,
                      kv_input=enc)


# -- satellite 3: pmm records non-routable operands before bailing -----------

def test_pmm_records_batched_weight_before_bailing():
    x = jnp.asarray(np.random.default_rng(10).standard_normal((2, 3, 4)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(11).standard_normal((2, 4, 5)),
                    jnp.float32)
    ctx = GemmContext(mesh=None)                # record-only
    with shard_ctx.gemm_context(ctx):
        out = pmm(x, w, tag="bmm")
    np.testing.assert_array_equal(out, x @ w)   # bitwise: stays out of the way
    # the old early-return skipped record(): the observed workload silently
    # undercounted every batched-weight einsum routed through pmm
    assert ctx.stats.observed == {("bmm", GEMMShape(6, 5, 4)): 1}
    assert ctx.stats.unroutable == 1


# -- satellite 4: MLA absorbed-form per-head accounting ----------------------

def test_mla_absorbed_decode_counts_per_head():
    cfg = smoke_config("deepseek-v2-236b")
    rng = np.random.default_rng(12)
    p = mla_params(jax.random.PRNGKey(1), cfg)
    b, max_len = 2, 8
    cache = {
        "c_kv": jnp.asarray(rng.standard_normal(
            (b, max_len, cfg.kv_lora_rank)), cfg.dtype),
        "k_rope": jnp.asarray(rng.standard_normal(
            (b, max_len, 1, cfg.rope_head_dim)), cfg.dtype),
        "index": jnp.asarray(4, jnp.int32),
    }
    x = jnp.asarray(rng.standard_normal((b, 1, cfg.d_model)), cfg.dtype)
    ctx = GemmContext(mesh=None)                # record-only
    with shard_ctx.gemm_context(ctx):
        mla_attention(p, x, cfg, jnp.array([4], jnp.int32), cache=cache)
    r, dn = cfg.kv_lora_rank, cfg.nope_head_dim
    # the absorbed einsums are n_heads independent per-head contractions;
    # a single record undercounted the decode workload ~n_heads x
    assert ctx.stats.observed[("mla.q_absorb", GEMMShape(b, r, dn))] \
        == cfg.n_heads
    assert ctx.stats.observed[("mla.v_unabsorb", GEMMShape(b, dn, r))] \
        == cfg.n_heads
    # and the planner's workload agrees (membership-based: multiplicity
    # lives in the observed counts)
    workload = model_workload(cfg, b, max_len, kind="decode")
    assert GEMMShape(b, r, dn) in workload
    assert GEMMShape(b, dn, r) in workload
    # the attention problem itself lands in the attention workload
    assert any(tag == "mla.decode"
               for (tag, _) in ctx.stats.attn_observed)


# -- routed multidevice proof (subprocess; slow) -----------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ROUTED_ATTENTION_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import smoke_config
    from repro.deploy import Planner, model_workload
    from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                                 TileConfig)
    from repro.models import shard_ctx
    from repro.models.model import decode_init, decode_step, init_params
    from repro.models.shard_ctx import GemmContext

    MINI = AcceleratorConfig(name="mini", grid=(4, 1),
                             tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                             noc=NoCConfig(), hbm=HBMConfig(n_channels=8))

    for name in ("gemma-2b", "deepseek-v2-236b"):
        cfg = smoke_config(name)
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.zeros((4, 1), jnp.int32)

        # unrouted baseline
        caches = decode_init(params, cfg, batch=4, max_len=8)
        base, _ = decode_step(params, caches, toks, jnp.asarray(0, jnp.int32),
                              cfg)
        base = np.asarray(base, np.float32)

        planner = Planner(MINI, elem_bytes=4, max_candidates=8)
        planner.batch_tune(model_workload(cfg, 4, 8, kind="decode"),
                           skip_illegal=True)
        mesh = jax.make_mesh((4, 1), ("data", "model"))
        ctx = GemmContext(mesh=mesh, planner=planner)
        shard_ctx.set_gemm_context(ctx)
        caches = decode_init(params, cfg, batch=4, max_len=8)
        routed, _ = decode_step(params, caches, toks,
                                jnp.asarray(0, jnp.int32), cfg)
        routed = np.asarray(routed, np.float32)
        shard_ctx.set_gemm_context(None)

        s = ctx.stats
        assert s.routed > 0, name
        assert s.fallback == 0, (name, s.describe())
        assert s.resolve_rate == 1.0, (name, s.describe())
        assert s.silent_degrades == 0, (name, s.describe())
        # decode (sq=1) lowers to the merge composition of the fused mode
        assert s.modes.get("flat_merge", 0) > 0, (name, s.modes)
        assert s.modes.get("unfused_attn", 0) == 0, (name, s.modes)
        assert s.attn_observed, name
        np.testing.assert_allclose(routed, base, rtol=5e-2, atol=5e-2)
        print(name, "modes:", s.modes)
    print("ALL_OK")
""")


@pytest.mark.slow
def test_routed_attention_multidevice():
    """GQA and MLA decode route through the fused attention mode on a real
    multi-device mesh: resolve rate 1.0, zero plan misses, zero silent
    degrades, and the routed logits match the unrouted baseline."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", ROUTED_ATTENTION_BODY],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout
