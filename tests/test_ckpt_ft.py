"""Checkpointing + fault-tolerance substrate."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.runtime import FailureInjector, Heartbeat, LoopConfig, run_training
from repro.optim import adamw, compress


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((4,), jnp.bfloat16),
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree, extra={"note": "hi"})
    assert mgr.latest_step() == 5
    out = mgr.restore(5, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert mgr.restore_extra(5)["note"] == "hi"


def test_atomic_no_partial_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    # a leftover tmp dir must never be picked up as latest
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp"))
    assert mgr.latest_step() == 1


def test_gc_keeps_recent(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(str(tmp_path)))
    assert steps == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = dict(_tree())
    bad["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(1, bad)


def test_heartbeat_staleness(tmp_path):
    hb = Heartbeat(str(tmp_path), host=0)
    hb.beat()
    assert Heartbeat.stale_hosts(str(tmp_path), timeout=60) == []
    assert Heartbeat.stale_hosts(str(tmp_path), timeout=-1) == [0]


def test_data_pipeline_deterministic_and_elastic():
    d1 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=8))
    b1 = d1.batch(42)
    b2 = d1.batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # elastic reshard: 2 hosts together cover different shards deterministically
    h0 = d1.reshard(0, 2)
    h1 = d1.reshard(1, 2)
    a, b = h0.batch(7)["tokens"], h1.batch(7)["tokens"]
    assert a.shape == (4, 16) and b.shape == (4, 16)
    assert not np.array_equal(a, b)


def test_run_training_resumes_from_checkpoint(tmp_path):
    calls = []

    def step_fn(state, batch):
        calls.append(int(state))
        return state + 1, {"loss": 0.0}

    data = SyntheticLM(DataConfig(vocab=10, seq_len=4, global_batch=2))
    inj = FailureInjector(fail_at={7})
    out = run_training(step_fn, jnp.asarray(0), data,
                       LoopConfig(total_steps=10, ckpt_every=5,
                                  ckpt_dir=str(tmp_path)),
                       make_batch_arrays=lambda b: b, injector=inj)
    # failed at 7, resumed from ckpt at step 4 (saved after step index 4)
    assert int(out) == 10
    assert 7 in calls


def test_compression_error_feedback_preserves_signal():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)) * 1e-3, jnp.float32)
    state = compress.init({"g": g})
    total_sent = jnp.zeros_like(g)
    gs = {"g": g}
    st = state
    for _ in range(10):
        sent, st = compress.apply(gs, st)
        total_sent = total_sent + sent["g"]
    # over steps, error feedback means sum of transmitted ~= sum of true grads
    np.testing.assert_allclose(np.asarray(total_sent), np.asarray(g * 10),
                               rtol=0.05, atol=2e-4)
