"""Observability layer: span lifecycle, Perfetto export schema, metrics
snapshots, drift monitoring, GemmStats round-trip, run-report assembly —
plus a slow multidevice subprocess proving a routed serve run emits a
complete `run_report.json` (full plan provenance, zero silent degrades)
and a loadable Chrome trace."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.schedule import GEMMShape
from repro.hw.config import tpu_pod_as_accelerator
from repro.models.shard_ctx import GemmStats
from repro.obs import (DRIFT_STALE_THRESHOLD, DriftMonitor, MetricsRegistry,
                       RUN_REPORT_SCHEMA_VERSION, Tracer, build_run_report,
                       describe_routing, get_tracer, render_run_report,
                       set_tracer, tracing, write_run_report)
from repro.obs.trace import CAT_PMM, CAT_STEP, maybe_span
from repro.sim.calibrate import CalibrationProfile, CalibrationSample
from repro.sim.perf import PerfReport


# ---------------------------------------------------------------------------
# tracer: span lifecycle + Chrome trace-event export
# ---------------------------------------------------------------------------

def test_span_lifecycle_records_complete_event():
    tracer = Tracer(process_name="t")
    with tracer.span("pmm.attn.q", tag="attn.q", shape=[8, 16, 32]) as args:
        args["provenance"] = "hit"
    (ev,) = tracer.events
    assert ev["ph"] == "X" and ev["cat"] == CAT_PMM
    assert ev["name"] == "pmm.attn.q"
    assert ev["dur"] >= 0 and ev["ts"] >= 0
    # mid-span provenance lands in the event args, plus the measured dur
    assert ev["args"]["provenance"] == "hit"
    assert ev["args"]["shape"] == [8, 16, 32]
    assert ev["args"]["dur_us"] == ev["dur"]


def test_span_records_even_when_body_raises():
    tracer = Tracer()
    with pytest.raises(RuntimeError):
        with tracer.span("pmm.x", tag="x"):
            raise RuntimeError("boom")
    assert len(tracer.events) == 1


def test_event_cap_drops_not_grows():
    tracer = Tracer(max_events=2)
    for i in range(5):
        with tracer.span("s", i=i):
            pass
    assert len(tracer.events) == 2 and tracer.dropped == 3
    assert tracer.to_chrome_trace()["otherData"]["dropped_events"] == 3


def test_chrome_trace_is_perfetto_loadable_schema(tmp_path):
    tracer = Tracer(process_name="serve.test")
    with tracer.span("pmm.ffn.up", cat=CAT_PMM, tag="ffn.up"):
        pass
    tracer.instant("pmm.probe", provenance="unrouted")
    path = tracer.write(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    # the Chrome trace-event envelope Perfetto's JSON importer requires
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped_events"] == 0
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["name"] == "process_name"
    assert meta[0]["args"]["name"] == "serve.test"
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert isinstance(e["ts"], (int, float)) and e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"
    # everything must be JSON-serializable without a custom encoder
    json.dumps(doc)


def test_global_tracer_install_and_maybe_span():
    assert get_tracer() is None
    with maybe_span("noop") as args:      # no tracer installed: a no-op
        assert args is None
    tracer = Tracer()
    with tracing(tracer):
        assert get_tracer() is tracer
        with maybe_span("serve.decode_token", position=3) as args:
            assert args is not None
    assert get_tracer() is None
    (ev,) = tracer.events
    assert ev["cat"] == CAT_STEP and ev["args"]["position"] == 3


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_snapshot():
    reg = MetricsRegistry()
    reg.counter("pmm.provenance.hit").inc()
    reg.counter("pmm.provenance.hit").inc(2)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.observe("pmm.dispatch_us.mode.summa", v)
    snap = reg.to_dict()
    assert snap["counters"] == {"pmm.provenance.hit": 3}
    h = snap["histograms"]["pmm.dispatch_us.mode.summa"]
    assert h["count"] == 4 and h["sum"] == 10.0
    assert h["min"] == 1.0 and h["max"] == 4.0 and h["mean"] == 2.5
    assert h["p50"] <= h["p95"] <= h["p99"] <= h["max"]
    json.dumps(snap)
    # the zero-observation snapshot carries the full percentile schema too
    # (the serving section's SLO accounting indexes p99 unconditionally)
    empty = MetricsRegistry().histogram("never.observed").to_dict()
    assert empty["count"] == 0
    assert {"p50", "p95", "p99"} <= set(empty)
    assert empty["p99"] == 0.0


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------

def _report(total=1e-3, steps=4) -> PerfReport:
    return PerfReport(total_time=total, compute_time=total * 0.5,
                      dma_time=total * 0.3, noc_time=total * 0.2,
                      barrier_time=0.0, total_flops=10**9, hbm_bytes=10**6,
                      noc_bytes=10**5, n_supersteps=steps)


def _samples(measured_scale: float, n=6):
    hw = tpu_pod_as_accelerator((4, 4))
    profile = CalibrationProfile.identity(hw, n_samples=n, fit_ok=True)
    samples = []
    for i in range(n):
        rep = _report(total=1e-3 * (i + 1))
        mode = "summa" if i % 2 == 0 else "cannon"
        samples.append(CalibrationSample(
            shape=(64, 64, 64), dataflow=mode, mode=mode, report=rep,
            measured_s=profile.predict(rep) * measured_scale))
    return profile, samples


def test_drift_monitor_flags_mis_scaled_profile():
    """A profile predicting 2.1x too fast trips the staleness flag."""
    profile, samples = _samples(measured_scale=2.1)
    mon = DriftMonitor(profile)
    assert mon.add_samples(samples) == len(samples)
    s = mon.summary()
    assert s["profile_stale"] is True
    assert s["drift_distance"] > DRIFT_STALE_THRESHOLD
    assert s["geomean_ratio"] == pytest.approx(2.1, rel=1e-3)
    assert set(s["per_mode"]) == {"summa", "cannon"}
    for rec in s["per_mode"].values():
        assert rec["geomean_ratio"] == pytest.approx(2.1, rel=1e-3)
    assert s["profile_digest"] == profile.digest()
    assert s["profile_trusted"] is True


def test_drift_monitor_accepts_accurate_profile():
    profile, samples = _samples(measured_scale=1.05)
    mon = DriftMonitor(profile)
    mon.add_samples(samples)
    s = mon.summary()
    assert s["profile_stale"] is False
    assert s["geomean_ratio"] == pytest.approx(1.05, rel=1e-3)


def test_drift_staleness_is_symmetric():
    """Predicting too slow is as stale as predicting too fast."""
    profile, samples = _samples(measured_scale=1 / 2.1)
    mon = DriftMonitor(profile)
    mon.add_samples(samples)
    s = mon.summary()
    assert s["profile_stale"] is True
    assert s["drift_distance"] == pytest.approx(2.1, rel=1e-3)


def test_drift_monitor_edge_cases():
    mon = DriftMonitor()
    mon.add("summa", 0.0, 1.0)          # non-positive prediction: skipped
    mon.add("summa", 1.0, -1.0)         # non-positive measurement: skipped
    assert mon.n_samples == 0
    s = mon.summary()
    assert s["profile_stale"] is False and s["n_samples"] == 0
    with pytest.raises(ValueError):
        DriftMonitor(threshold=0.5)


# ---------------------------------------------------------------------------
# GemmStats round-trip + the single-sourced routing line
# ---------------------------------------------------------------------------

def test_gemm_stats_roundtrip_and_describe():
    s = GemmStats()
    s.hits, s.bucketed, s.fallback, s.unrouted = 3, 1, 1, 2
    s.modes = {"summa": 3, "auto": 2}
    s.degrades = {"grid_mismatch": 1}
    s.silent_degrades = 0
    s.observed[("attn.q", GEMMShape(8, 16, 32))] = 4
    d = s.to_dict()
    json.dumps(d)
    assert d["calls"] == 7 and d["routed"] == 5 and d["unrouted"] == 2
    assert d["resolve_rate"] == pytest.approx(4 / 5)
    assert d["silent_degrades"] == 0
    assert d["observed"] == [{"tag": "attn.q", "shape": [8, 16, 32],
                              "count": 4}]
    # round-trip preserves the snapshot
    s2 = GemmStats.from_dict(d)
    assert s2.to_dict() == d
    # the print IS the dict: describe() delegates to describe_routing()
    assert s.describe() == describe_routing(d)
    assert "plan-resolve-rate=80%" in s.describe()


# ---------------------------------------------------------------------------
# run report: build, write, render
# ---------------------------------------------------------------------------

def test_run_report_build_write_render(tmp_path):
    tracer = Tracer(process_name="serve.t")
    with tracer.span("pmm.attn.q", cat=CAT_PMM, tag="attn.q",
                     shape=[8, 16, 32]) as args:
        args.update(provenance="hit", mode="summa", plan_digest="abc123")
    stats = {"calls": 1, "routed": 1, "hits": 1, "bucketed": 0,
             "fallback": 0, "unrouted": 0, "resolve_rate": 1.0,
             "modes": {"summa": 1}, "degrades": {}, "silent_degrades": 0,
             "observed": []}
    profile, samples = _samples(measured_scale=2.1)
    mon = DriftMonitor(profile)
    mon.add_samples(samples)
    report = build_run_report("serve", stats=stats, drift=mon.summary(),
                              tracer=tracer, extra={"arch": "t"})
    assert report["schema_version"] == RUN_REPORT_SCHEMA_VERSION
    assert report["launcher"] == "serve" and report["arch"] == "t"
    assert "workload" not in report          # None sections are omitted
    (disp,) = report["dispatches"]
    assert disp["name"] == "pmm.attn.q" and disp["provenance"] == "hit"
    assert disp["plan_digest"] == "abc123" and "dur_us" in disp

    path = str(tmp_path / "sub" / "run_report.json")
    write_run_report(path, report)
    assert json.load(open(path)) == json.loads(json.dumps(report))

    lines = render_run_report(report)
    assert any(l.startswith("plan routing: pmm calls=1") for l in lines)
    assert any("lowered modes" in l for l in lines)
    assert any("calibration drift" in l and "STALE" in l for l in lines)


def test_exec_plan_to_dict_is_jsonable():
    import jax

    from repro.core.lower import lower_schedule
    from repro.core.schedule import Schedule, Tiling

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sched = Schedule(GEMMShape(64, 64, 64), Tiling(1, 1, 1, tk=64), "summa",
                     inner=(1, 1))
    ep = lower_schedule(sched, mesh, shape=(64, 64, 64))
    d = ep.to_dict()
    json.dumps(d)
    assert d["requested"] == "summa"
    assert d["shape"] == [64, 64, 64]
    assert isinstance(d["degraded"], bool)
    assert all({"reason", "from", "to"} <= set(f) for f in d["fallbacks"])


# ---------------------------------------------------------------------------
# traced routed dispatch: provenance lands in the spans (single device)
# ---------------------------------------------------------------------------

def test_pmm_dispatch_emits_provenance_spans():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.deploy import Planner
    from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                                 TileConfig)
    from repro.models import shard_ctx
    from repro.models.matmul import pmm

    mini = AcceleratorConfig(name="mini", grid=(4, 4),
                             tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                             noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
    planner = Planner(mini, elem_bytes=4, max_candidates=4)
    shape = GEMMShape(64, 32, 16)
    planner.batch_tune([shape])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = shard_ctx.GemmContext(mesh=mesh, planner=planner)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)

    tracer = Tracer()
    with tracing(tracer), shard_ctx.gemm_context(ctx):
        jax.jit(lambda a, b: pmm(a, b, tag="probe")).lower(x, w)
        pmm(x, w)                        # untagged + unjitted also traced

    spans = tracer.spans(CAT_PMM)
    assert len(spans) == 2
    by_name = {e["name"]: e["args"] for e in spans}
    prov = by_name["pmm.probe"]
    assert prov["provenance"] == "hit" and prov["tag"] == "probe"
    assert prov["shape"] == [64, 32, 16]
    assert prov["plan_digest"] and prov["plan_resolve_us"] >= 0
    assert prov["predicted_s"] > 0 and prov["mode"]
    assert "pmm.untagged" in by_name
    # the dispatch metrics rode along
    snap = tracer.metrics.to_dict()
    assert snap["counters"]["pmm.provenance.hit"] == 2
    assert any(k.startswith("pmm.dispatch_us.mode.")
               for k in snap["histograms"])


def test_untraced_dispatch_unchanged():
    """No tracer installed: routing still works, nothing recorded."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import shard_ctx
    from repro.models.matmul import pmm

    ctx = shard_ctx.GemmContext(mesh=None)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    assert get_tracer() is None
    with shard_ctx.gemm_context(ctx):
        out = pmm(x, w, tag="probe")
    assert out.shape == (8, 8) and ctx.stats.unrouted == 1


# ---------------------------------------------------------------------------
# the end-to-end proof: routed serve run emits a complete run report
# (multidevice, subprocess — keeps fake devices out of this process)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SERVE_REPORT_BODY = textwrap.dedent("""
    import json
    import subprocess
    import sys

    out = sys.argv[1]
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma-2b",
         "--smoke", "--batch", "2", "--prompt-len", "4", "--gen", "4",
         "--plan-candidates", "4", "--plan-cache", out + "/cache",
         "--run-report", out + "/run_report.json",
         "--trace", out + "/trace.json"],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]

    r = json.load(open(out + "/run_report.json"))
    assert r["schema_version"] == 1 and r["launcher"] == "serve"
    routing = r["routing"]
    assert routing["calls"] > 0
    assert routing["calls"] == routing["routed"], routing
    assert routing["unrouted"] == 0 and routing["resolve_rate"] == 1.0
    assert routing["silent_degrades"] == 0, routing
    assert r["workload"]["covered"] == 1.0, r["workload"]
    # every dispatch carries full plan provenance. GEMM rows come from the
    # warmed cache (hits); attention rows (pattn.*) resolve online from the
    # closed-form menu, so "analytic" joins their vocabulary, and their
    # shape is the 7-dim attention problem, not (m, n, k)
    assert r["dispatches"], "no pmm spans recorded"
    attn_rows = [d for d in r["dispatches"] if d["name"].startswith("pattn.")]
    assert attn_rows, "attention never routed through pattn"
    for d in r["dispatches"]:
        if d["name"].startswith("pattn."):
            assert d["provenance"] in ("hit", "bucketed", "analytic",
                                       "fallback"), d
            assert d["tag"] and len(d["shape"]) == 7, d
            if d["provenance"] != "fallback":
                assert d["attn_schedule"], d
        else:
            assert d["provenance"] in ("hit", "bucketed", "fallback"), d
            assert d["tag"] and len(d["shape"]) == 3, d
            assert d["plan_digest"], d
        assert d["plan_resolve_us"] >= 0 and d["dur_us"] >= 0, d
    assert r["metrics"]["counters"], r["metrics"]
    # the trace next to it is a loadable Chrome trace document
    t = json.load(open(out + "/trace.json"))
    assert t["displayTimeUnit"] == "ms" and t["traceEvents"]
    cats = {e.get("cat") for e in t["traceEvents"]}
    assert {"pmm", "step"} <= cats, cats
    # the shutdown print renders from the same dict the report persists
    from repro.obs import describe_routing
    assert ("plan routing: " + describe_routing(routing)) in proc.stdout
    print("ALL_OK")
""")


@pytest.mark.slow
def test_serve_run_report_multidevice(tmp_path):
    """A routed multidevice serve run emits a complete run_report.json
    (full provenance, zero silent degrades) + a loadable Chrome trace."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    proc = subprocess.run(
        [sys.executable, "-c", SERVE_REPORT_BODY, str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout
