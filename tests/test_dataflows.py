"""Functional verification of every dataflow pattern primitive against the
numpy GEMM oracle — the paper's 'numerical verification' workflow stage —
plus structural properties of the generated BSP programs."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ir import DMAOp, MulticastOp
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.sim.perf import estimate
from repro.sim.softhier import verify_gemm

HW = AcceleratorConfig(name="mini", grid=(4, 4),
                       tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                       noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
HW2 = AcceleratorConfig(name="mini8", grid=(8, 8),
                        tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                        noc=NoCConfig(), hbm=HBMConfig(n_channels=16))


def _rand(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((m, k)).astype(np.float32),
            rng.standard_normal((k, n)).astype(np.float32))


BASE_CASES = [
    ("baseline", Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=32), "baseline")),
    ("summa", Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=32), "summa")),
    ("systolic", Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=32), "systolic")),
    ("splitk", Schedule(GEMMShape(64, 64, 128), Tiling(2, 2, 4, tk=16), "splitk_summa")),
    ("sys/summa", Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=16),
                           "systolic_over_summa", inner=(2, 2))),
    ("summa/sys", Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=16),
                           "summa_over_systolic", inner=(2, 2))),
]


@pytest.mark.parametrize("name,sched", BASE_CASES, ids=[c[0] for c in BASE_CASES])
def test_dataflow_correct(name, sched):
    m, n, k = sched.shape.m, sched.shape.n, sched.shape.k
    a, b = _rand(m, n, k)
    verify_gemm(build_program(sched, HW), a, b)


@pytest.mark.parametrize("name,sched", BASE_CASES, ids=[c[0] for c in BASE_CASES])
def test_dataflow_correct_no_double_buffer(name, sched):
    import dataclasses
    sched = dataclasses.replace(sched, double_buffer=False)
    a, b = _rand(sched.shape.m, sched.shape.n, sched.shape.k, seed=7)
    verify_gemm(build_program(sched, HW), a, b)


@given(gm=st.sampled_from([2, 4]), gn=st.sampled_from([2, 4]),
       tk=st.sampled_from([16, 32]), seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_summa_property(gm, gn, tk, seed):
    gk = 16 // (gm * gn)
    t = Tiling(gm, gn, gk, tk=tk) if gk > 1 else Tiling(gm, gn, 1, tk=tk)
    df = "splitk_summa" if gk > 1 else "summa"
    sched = Schedule(GEMMShape(64, 64, 128), t, df)
    a, b = _rand(64, 64, 128, seed)
    verify_gemm(build_program(sched, HW), a, b)


@given(iter_m=st.sampled_from([1, 2]), iter_n=st.sampled_from([1, 2]),
       stages=st.sampled_from([1, 2, 4]))
@settings(max_examples=12, deadline=None)
def test_summa_iterations_and_store_stages(iter_m, iter_n, stages):
    sched = Schedule(GEMMShape(128, 128, 64),
                     Tiling(4, 4, 1, iter_m=iter_m, iter_n=iter_n, tk=32),
                     "summa", store_stages=stages)
    a, b = _rand(128, 128, 64, seed=3)
    verify_gemm(build_program(sched, HW), a, b)


def test_remapped_flat_gemm():
    """Insight 4: flat GEMM with a 1 x (gn*gk) logical view of the 4x4 grid."""
    sched = Schedule(GEMMShape(16, 64, 256), Tiling(1, 4, 4, tk=16),
                     "splitk_summa")
    a, b = _rand(16, 64, 256, seed=11)
    verify_gemm(build_program(sched, HW), a, b)


def test_split_k_owner_policies():
    for policy in ("first", "round_robin"):
        sched = Schedule(GEMMShape(32, 32, 128), Tiling(2, 2, 4, tk=16),
                         "splitk_summa", reduce_owner=policy)
        a, b = _rand(32, 32, 128, seed=2)
        verify_gemm(build_program(sched, HW), a, b)


def test_8x8_grid():
    sched = Schedule(GEMMShape(128, 128, 128), Tiling(8, 8, 1, tk=32), "summa")
    a, b = _rand(128, 128, 128, seed=5)
    verify_gemm(build_program(sched, HW2), a, b)


def test_hierarchical_4x4_inner_on_8x8():
    sched = Schedule(GEMMShape(128, 128, 256), Tiling(8, 8, 1, tk=16),
                     "systolic_over_summa", inner=(4, 4))
    a, b = _rand(128, 128, 256, seed=6)
    verify_gemm(build_program(sched, HW2), a, b)


# -- structural properties ----------------------------------------------------

def test_summa_reads_each_input_once():
    """SUMMA's whole point: A and B leave HBM exactly once (high intensity)."""
    sched = Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=32), "summa")
    prog = build_program(sched, HW)
    loads_a = loads_b = 0
    for step in prog.supersteps:
        for op in step.comm:
            if isinstance(op, DMAOp) and op.kind == "load":
                if op.matrix == "A":
                    loads_a += 1
                else:
                    loads_b += 1
    tm, tn, tk = prog.tile_shape
    assert loads_a * tm * tk == 64 * 128      # A read exactly once
    assert loads_b * tk * tn == 128 * 64      # B read exactly once


def test_baseline_amplifies_hbm_reads():
    sched = Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=32), "baseline")
    prog = build_program(sched, HW)
    counts = prog.op_counts()
    assert counts["multicast"] == 0
    # every tile fetches its own copy: gn-fold amplification for A + B
    assert prog.hbm_bytes(4) > 3 * GEMMShape(64, 64, 128).min_bytes(4)


def test_perf_orderings():
    """Cost-model sanity: optimized dataflow strictly beats baseline, and the
    base (single-channel) layout is strictly worse than the optimal one."""
    import dataclasses
    from repro.core.layout import base_layout
    shape = GEMMShape(256, 256, 256)
    summa = Schedule(shape, Tiling(4, 4, 1, tk=64), "summa")
    base = Schedule(shape, Tiling(4, 4, 1, tk=64), "baseline")
    t_summa = estimate(build_program(summa, HW), HW).total_time
    t_base = estimate(build_program(base, HW), HW).total_time
    assert t_summa < t_base
    bad_layouts = {m: base_layout(s, 64, 64, HW.hbm.n_channels)
                   for m, s in (("A", (256, 256)), ("B", (256, 256)), ("C", (256, 256)))}
    summa_bad = dataclasses.replace(summa, layouts=bad_layouts)
    t_bad = estimate(build_program(summa_bad, HW), HW).total_time
    assert t_summa < t_bad


def test_l1_capacity_enforced():
    small = AcceleratorConfig(name="tiny-l1", grid=(4, 4),
                              tile=TileConfig(l1_bytes=1024),
                              noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
    sched = Schedule(GEMMShape(64, 64, 128), Tiling(4, 4, 1, tk=32), "summa")
    with pytest.raises(ValueError, match="L1"):
        build_program(sched, small)
