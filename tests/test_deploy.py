"""Deployment-plan subsystem: artifact round-trip, cache semantics,
fingerprint invalidation, bucketed-transfer quality vs a fresh tune, and the
planner's warm-path contract (no enumeration on a hit)."""
import dataclasses
import json
import os
import time
from types import SimpleNamespace

import pytest

from repro.core.autotuner import enumerate_candidates, tune, tune_cached
from repro.core.layout import optimal_layout
from repro.core.lower import lower_schedule
from repro.core.remap import ClusterRemap
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.deploy import (BucketingPolicy, DeploymentPlan, PlanCache, Planner,
                          SOURCE_BUCKETED, SOURCE_TUNED, adapt, bucket_of,
                          hw_fingerprint, model_workload, plan_from_tuning)
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.sim.perf import estimate

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
MINI_BIG_L1 = AcceleratorConfig(name="mini-big-l1", grid=(4, 4),
                                tile=TileConfig(l1_bytes=8 * 1024 * 1024),
                                noc=NoCConfig(), hbm=HBMConfig(n_channels=8))

SHAPE = GEMMShape(256, 256, 256)


def make_plan(shape=SHAPE, hw=MINI, **tune_kw):
    res = tune(shape, hw, elem_bytes=4, max_candidates=16, **tune_kw)
    return plan_from_tuning(shape, hw, res.schedule, res.report,
                            candidates_tried=res.candidates_tried)


def make_planner(hw=MINI, cache=None, **kw):
    return Planner(hw, cache=cache, elem_bytes=4, max_candidates=16, **kw)


# ---------------------------------------------------------------------------
# plan artifact
# ---------------------------------------------------------------------------

def test_plan_json_round_trip():
    plan = make_plan()
    back = DeploymentPlan.from_json(plan.to_json())
    assert back.schedule == plan.schedule
    assert back.report == plan.report
    assert back.hw_digest == plan.hw_digest
    assert back.source == SOURCE_TUNED


def test_plan_round_trip_with_remap_and_layouts():
    sched = Schedule(SHAPE, Tiling(2, 8, 1, tk=64), "summa",
                     remap=ClusterRemap((4, 4), (2, 8)),
                     layouts={"A": optimal_layout((256, 256), 128, 32, 8)},
                     store_stages=4, reduce_owner="round_robin",
                     elem_bytes=4)
    rep = estimate(build_program(sched, MINI), MINI)
    plan = plan_from_tuning(SHAPE, MINI, sched, rep)
    back = DeploymentPlan.from_json(plan.to_json())
    assert back.schedule == sched
    # the deserialized schedule must still build
    assert build_program(back.schedule, MINI).supersteps


def test_plan_schema_version_rejected():
    d = make_plan().to_dict()
    d["schema_version"] = 999
    with pytest.raises(ValueError, match="schema version"):
        DeploymentPlan.from_dict(d)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_cache_hit_miss_semantics():
    cache = PlanCache()
    assert cache.get(SHAPE, 4, MINI) is None
    assert cache.stats.misses == 1
    plan = make_plan()
    cache.put(plan)
    got = cache.get(SHAPE, 4, MINI)
    assert got is plan
    assert cache.stats.hits == 1
    # different elem_bytes is a different tuning problem
    assert cache.get(SHAPE, 1, MINI) is None


def test_cache_persistence_round_trip(tmp_path):
    cache = PlanCache(str(tmp_path))
    cache.put(make_plan())
    reloaded = PlanCache(str(tmp_path))
    got = reloaded.peek(SHAPE, 4, MINI)
    assert got is not None
    assert got.schedule == cache.peek(SHAPE, 4, MINI).schedule


def test_cache_ignores_corrupt_and_foreign_files(tmp_path):
    cache = PlanCache(str(tmp_path))
    cache.put(make_plan())
    (tmp_path / "garbage.plan.json").write_text("{not json")
    stale = make_plan().to_dict()
    stale["schema_version"] = 999
    (tmp_path / "stale.plan.json").write_text(json.dumps(stale))
    reloaded = PlanCache(str(tmp_path))
    assert len(reloaded) == 1


def test_hw_fingerprint_invalidation():
    cache = PlanCache()
    cache.put(make_plan(hw=MINI))
    # same grid, different L1 capacity -> different legality space -> miss
    assert hw_fingerprint(MINI) != hw_fingerprint(MINI_BIG_L1)
    assert cache.get(SHAPE, 4, MINI_BIG_L1) is None
    assert cache.get(SHAPE, 4, MINI) is not None


# ---------------------------------------------------------------------------
# autotuner integration
# ---------------------------------------------------------------------------

def test_enumerate_candidates_deduped():
    seen = set()
    for sched in enumerate_candidates(SHAPE, MINI, elem_bytes=4,
                                      max_candidates=256):
        key = (sched.tiling, sched.dataflow, sched.acc_bytes)
        assert key not in seen, f"duplicate candidate {sched.describe()}"
        seen.add(key)


def test_tune_cached_skips_enumeration_on_hit():
    cache = PlanCache()
    cold = tune_cached(SHAPE, MINI, cache, elem_bytes=4, max_candidates=16)
    warm = tune_cached(SHAPE, MINI, cache, elem_bytes=4, max_candidates=16)
    assert cold.candidates_tried > 0
    assert warm.candidates_tried == 0
    assert warm.schedule == cold.schedule
    assert warm.report.total_time == cold.report.total_time


# ---------------------------------------------------------------------------
# planner: warm path + bucketing
# ---------------------------------------------------------------------------

def test_planner_warm_path_no_enumeration(monkeypatch):
    planner = make_planner()
    cold = planner.plan(SHAPE)
    # a warm hit must never reach the autotuner
    import repro.deploy.planner as planner_mod

    def boom(*a, **k):
        raise AssertionError("tune called on the warm path")

    monkeypatch.setattr(planner_mod, "tune", boom)
    warm = planner.plan(SHAPE)
    assert warm is cold


def test_planner_warm_speedup():
    planner = make_planner()
    t0 = time.perf_counter()
    planner.plan(SHAPE)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(10):
        planner.plan(SHAPE)
    warm = (time.perf_counter() - t0) / 10
    assert cold / warm >= 10, f"warm path only {cold / warm:.1f}x faster"


def test_bucket_of_rounds_up_pow2():
    policy = BucketingPolicy(dim_cap=4096)
    assert bucket_of(GEMMShape(192, 256, 300), policy) == \
        GEMMShape(256, 256, 512)
    assert bucket_of(GEMMShape(100000, 8, 4096), policy) == \
        GEMMShape(4096, 8, 4096)


def test_adapt_reclamps_tk():
    src = tune(GEMMShape(256, 256, 512), MINI, elem_bytes=4,
               max_candidates=16).schedule
    # K shrinks to a value the tuned tk may not divide: adapt must re-derive
    adapted = adapt(src, GEMMShape(256, 256, 192), MINI)
    assert adapted is not None
    assert build_program(adapted, MINI).supersteps


def test_bucketed_lookup_within_tolerance_of_fresh_tune():
    planner = make_planner()
    planner.batch_tune([GEMMShape(256, 256, 256), GEMMShape(256, 256, 512),
                        GEMMShape(512, 256, 256)])
    probes = [GEMMShape(192, 256, 256), GEMMShape(256, 192, 256),
              GEMMShape(224, 224, 256), GEMMShape(256, 256, 384)]
    bucketed_ok = 0
    for probe in probes:
        plan = planner.plan(probe)
        assert build_program(plan.schedule, MINI).supersteps   # legal
        fresh = tune(probe, MINI, elem_bytes=4, max_candidates=16)
        ratio = plan.report.total_time / fresh.report.total_time
        assert ratio <= 1.0 + planner.policy.tolerance + 1e-9, (
            f"{probe}: bucketed plan {ratio:.2f}x the fresh tune")
        if plan.source == SOURCE_BUCKETED:
            bucketed_ok += 1
    # the acceptance bar: at least 3 probes actually served from buckets
    assert bucketed_ok >= 3


def test_bad_transfer_falls_back_to_full_tune():
    planner = make_planner()
    planner.plan(GEMMShape(512, 512, 256))
    # far-off aspect ratio: either no transfer attempt survives the expected-
    # time guard, or the transfer is genuinely within tolerance.
    plan = planner.plan(GEMMShape(32, 512, 256))
    fresh = tune(GEMMShape(32, 512, 256), MINI, elem_bytes=4,
                 max_candidates=16)
    assert plan.report.total_time <= \
        (1.0 + planner.policy.tolerance) * fresh.report.total_time


def test_restricted_planner_does_not_clobber_unrestricted(tmp_path):
    cache = PlanCache(str(tmp_path))
    p_free = make_planner(cache=cache)
    free_plan = p_free.plan(SHAPE)
    p_base = make_planner(cache=cache, dataflows=["baseline"])
    base_plan = p_base.plan(SHAPE)
    assert base_plan.schedule.dataflow == "baseline"
    # both variants coexist: each planner hits its own entry
    assert p_free.plan(SHAPE) is free_plan
    assert p_base.plan(SHAPE) is base_plan
    # and both survive a reload from disk
    reloaded = PlanCache(str(tmp_path))
    assert len(reloaded) == 2


def test_empty_dataflows_treated_as_unrestricted():
    # [] means 'unrestricted' to the tuner; the cache layers must agree or
    # every plan() call would re-tune forever.
    planner = make_planner(dataflows=[])
    assert planner.variant == ""
    p1 = planner.plan(SHAPE)
    puts = planner.cache.stats.puts
    assert planner.plan(SHAPE) is p1
    assert planner.cache.stats.puts == puts


def test_transfers_only_seed_from_tuned_plans():
    planner = make_planner()
    # a bucketed-source entry at the bucket shape must NOT seed transfers
    # (chained transfers would compound the tolerance loss per generation)
    res = tune(SHAPE, MINI, elem_bytes=4, max_candidates=16)
    planner.cache.put(plan_from_tuning(SHAPE, MINI, res.schedule, res.report,
                                       source=SOURCE_BUCKETED))
    plan = planner.plan(GEMMShape(224, 224, 256))
    assert plan.source == SOURCE_TUNED


def test_refinement_upgrades_bucketed_entries():
    planner = make_planner()
    planner.plan(GEMMShape(256, 256, 256))
    probe = GEMMShape(224, 224, 256)
    plan = planner.plan(probe)
    if plan.source != SOURCE_BUCKETED:
        pytest.skip("probe was not served from a bucket on this config")
    assert probe in planner.pending_refinements
    records = planner.refine_pending()
    assert [s for s, _, _ in records] == [probe]
    assert not planner.pending_refinements
    refined = planner.cache.peek(probe, 4, MINI)
    assert refined.source == SOURCE_TUNED
    assert refined.report.total_time <= plan.report.total_time


def test_refine_async_executor():
    from concurrent.futures import ThreadPoolExecutor
    planner = make_planner()
    planner.plan(GEMMShape(256, 256, 256))
    plan = planner.plan(GEMMShape(192, 256, 256))
    if plan.source != SOURCE_BUCKETED:
        pytest.skip("probe was not served from a bucket on this config")
    with ThreadPoolExecutor(max_workers=1) as ex:
        futures = planner.refine_async(ex)
        results = [f.result() for f in futures]
    assert results and not planner.pending_refinements


# ---------------------------------------------------------------------------
# dispatch + workload extraction
# ---------------------------------------------------------------------------

def test_lower_schedule_mapping():
    """The deploy-facing contract of the schedule->mesh lowering: tuned
    dataflows resolve to their mesh modes (tests/test_lowering.py covers the
    full fallback-reason matrix)."""
    mesh_sq = SimpleNamespace(shape={"data": 2, "model": 2})
    mesh_rect = SimpleNamespace(shape={"data": 1, "model": 4})

    def sched(df, owner="first", gk=1):
        return Schedule(SHAPE, Tiling(4, 4, gk, tk=64), df,
                        reduce_owner=owner)

    assert lower_schedule(sched("summa"), mesh_sq).mode == "summa"
    assert lower_schedule(sched("systolic"), mesh_sq).mode == "cannon"
    ep = lower_schedule(sched("systolic"), mesh_rect)
    assert ep.mode == "summa" and "non_square_systolic" in ep.reasons()
    assert lower_schedule(sched("baseline"), mesh_sq).mode == "allgather"
    # the tuned 3-D grid survives: gk=2 factors out of the model axis
    ep = lower_schedule(sched("splitk_summa", "round_robin", gk=2), mesh_sq)
    assert ep.mode == "splitk_summa" and ep.kwargs["scatter"] is True
    assert ep.axes["k"] == "splitk" and not ep.fallbacks
    ep = lower_schedule(sched("splitk_summa", "first", gk=2), mesh_sq)
    assert ep.kwargs["scatter"] is False
    # a k-grid that factors into neither axis collapses to 1-D split-K,
    # with the reason recorded
    ep = lower_schedule(sched("splitk_summa", "round_robin", gk=3), mesh_sq)
    assert ep.mode == "splitk" and "grid_mismatch" in ep.reasons()


def test_model_workload_extraction():
    cfg = SimpleNamespace(d_model=64, hd=16, n_heads=4, n_kv_heads=2,
                          d_ff=256, vocab=1000, attn="gqa", n_experts=0,
                          moe_top_k=0, moe_d_ff=0, q_lora_rank=0,
                          kv_lora_rank=0, rope_head_dim=0, nope_head_dim=0)
    shapes = model_workload(cfg, batch=2, seq=8, kind="prefill")
    assert len(shapes) == len(set(shapes))          # deduped
    assert GEMMShape(16, 256, 64) in shapes         # FFN up at 16 tokens
    assert GEMMShape(16, 1000, 64) in shapes        # LM head
    decode = model_workload(cfg, batch=2, seq=8, kind="decode")
    assert GEMMShape(2, 256, 64) in decode          # M = batch for decode


def test_planner_end_to_end_batch_then_rerequest():
    """ISSUE acceptance: batch-tune a workload, re-request the same shapes,
    and observe pure cache hits (zero enumeration on the second pass)."""
    cfg = SimpleNamespace(d_model=64, hd=16, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=512, attn="gqa", n_experts=0,
                          moe_top_k=0, moe_d_ff=0, q_lora_rank=0,
                          kv_lora_rank=0, rope_head_dim=0, nope_head_dim=0)
    workload = model_workload(cfg, batch=4, seq=16, kind="prefill")
    planner = make_planner()
    first = planner.batch_tune(workload)
    hits_before = planner.cache.stats.hits
    second = {s: planner.plan(s) for s in workload}
    assert planner.cache.stats.hits == hits_before + len(set(workload))
    for s in workload:
        assert second[s] is first[s]
