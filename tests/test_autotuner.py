"""Autotuner: legality, insight-consistency, and the paper's headline
behaviours at GH200 scale (these double as fast regression checks on the
cost model)."""
import pytest

from repro.core.autotuner import enumerate_candidates, tune
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                             TileConfig, softhier_gh200)
from repro.sim.perf import estimate

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))


def test_candidates_are_legal():
    shape = GEMMShape(256, 256, 256)
    for sched in enumerate_candidates(shape, MINI, elem_bytes=4,
                                      max_candidates=24):
        prog = build_program(sched, MINI)       # raises if illegal
        assert prog.supersteps


def test_tune_beats_naive_baseline():
    shape = GEMMShape(256, 256, 512)
    res = tune(shape, MINI, elem_bytes=4, max_candidates=24)
    naive = estimate(build_program(
        Schedule(shape, Tiling(4, 4, 1, tk=64), "baseline"), MINI), MINI)
    assert res.report.total_time < naive.total_time


@pytest.mark.slow
def test_paper_insight3_3d_beats_2d_on_irregular_shape():
    hw = softhier_gh200()
    shape = GEMMShape(4096, 2112, 7168)
    two_d = estimate(build_program(
        Schedule(shape, Tiling(32, 32, 1, tk=128), "summa", elem_bytes=1), hw), hw)
    res = tune(shape, hw, elem_bytes=1, max_candidates=24)
    assert res.report.total_time < two_d.total_time
    assert res.schedule.tiling.gk > 1 or res.schedule.tiling.gn < 32


@pytest.mark.slow
def test_paper_insight4_remap_wins_flat_gemm():
    hw = softhier_gh200()
    shape = GEMMShape(64, 2112, 7168)
    res = tune(shape, hw, elem_bytes=1, max_candidates=24)
    two_d = estimate(build_program(
        Schedule(shape, Tiling(32, 32, 1, tk=224), "summa", elem_bytes=1), hw), hw)
    assert res.report.total_time < two_d.total_time / 2   # paper: large win
    # the winner uses a flat logical grid (gm small) with 3-D split
    assert res.schedule.tiling.gm <= 4 and res.schedule.tiling.gk >= 8


@pytest.mark.slow
def test_paper_fig12_portability():
    """Autotuned utilization stays high across A100- and GH200-sized
    instances (the paper's §4.2 claim)."""
    from repro.hw.config import softhier_a100
    shape = GEMMShape(4096, 4096, 7168)
    for hw in (softhier_a100(), softhier_gh200()):
        res = tune(shape, hw, elem_bytes=hw.tile.elem_bytes, max_candidates=16)
        assert res.report.utilization(hw) > 0.5, hw.name
