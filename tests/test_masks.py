"""Mask-based collective addressing: the paper's (i & M) == S group calculus
and its equivalence with binary sub-axis decomposition (the TPU lowering)."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.masks import (MaskSpec, all_group, axis_bits, col_group,
                              group_to_device_ids, mask_to_subaxes,
                              partition_grid, rect_group, row_group, single,
                              strided_group, subaxes_to_members)


def brute_force_members(spec: MaskSpec, extent: int):
    return [i for i in range(extent) if (i & spec.mask) == spec.selector]


@given(mask=st.integers(0, 31), sel=st.integers(0, 31))
def test_mask_spec_matches_formula(mask, sel):
    sel &= mask  # keep the group non-empty
    spec = MaskSpec(sel, mask)
    members = brute_force_members(spec, 32)
    assert members, "aligned selector must give a non-empty group"
    # group size is always a power of two: 2^(free bits)
    free = bin(~mask & 31).count("1")
    assert len(members) == 1 << free


@given(mask=st.integers(0, 63), sel=st.integers(0, 63))
@settings(max_examples=200)
def test_subaxis_decomposition_equivalence(mask, sel):
    """The paper's mask groups == binary sub-axis groups (DESIGN.md §2.2)."""
    sel &= mask
    spec = MaskSpec(sel, mask)
    free_bits, fixed = mask_to_subaxes(spec, 64)
    assert subaxes_to_members(free_bits, fixed, 64) == brute_force_members(spec, 64)


@pytest.mark.parametrize("grid", [(4, 4), (8, 8), (16, 16), (4, 16)])
def test_row_col_groups(grid):
    rows, cols = grid
    for i in range(rows):
        g = row_group(i, grid)
        assert g.members(grid) == [(i, j) for j in range(cols)]
    for j in range(cols):
        g = col_group(j, grid)
        assert g.members(grid) == [(i, j) for i in range(rows)]


def test_rect_group():
    grid = (8, 8)
    g = rect_group(4, 2, 2, 2, grid)
    assert g.members(grid) == [(4, 2), (4, 3), (5, 2), (5, 3)]
    with pytest.raises(ValueError):
        rect_group(3, 0, 2, 2, grid)       # unaligned origin
    with pytest.raises(ValueError):
        rect_group(0, 0, 3, 2, grid)       # non-power-of-2 size


def test_strided_group():
    grid = (8, 8)
    g = strided_group(1, 2, 0, 4, grid)
    expect = [(i, j) for i in range(8) for j in range(8) if i % 2 == 1 and j % 4 == 0]
    assert sorted(g.members(grid)) == sorted(expect)


def test_all_and_single():
    grid = (4, 4)
    assert len(all_group().members(grid)) == 16
    assert single(2, 3, grid).members(grid) == [(2, 3)]


def test_partition_grid_covers_disjointly():
    grid = (8, 8)
    groups = partition_grid(grid, (2, 4))
    seen = set()
    for g in groups:
        for m in g.members(grid):
            assert m not in seen
            seen.add(m)
    assert len(seen) == 64


def test_device_ids_row_major():
    grid = (4, 4)
    assert group_to_device_ids(row_group(1, grid), grid) == [4, 5, 6, 7]


def test_invalid_selector_rejected():
    with pytest.raises(ValueError):
        MaskSpec(selector=4, mask=3).validate()


def test_axis_bits_requires_pow2():
    assert axis_bits(16) == 4
    with pytest.raises(ValueError):
        axis_bits(12)
