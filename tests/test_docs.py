"""Docs-drift guard: the documentation tree cannot silently rot.

PR 3 left `core/dataflow/hierarchical.py` and `core/lower.py` claiming both
hierarchical compositions lower to one mode after the lowering layer moved
on — the kind of drift only a reader notices. These checks make the
load-bearing doc invariants mechanical:

- every `DATAFLOWS` name, every `EXEC_MODES` mode, and every machine-
  readable `Fallback` reason string appears in docs/dataflows.md (the
  lowering reference a degrade report sends you to);
- every relative link in README.md and docs/*.md resolves to a real file;
- the calibration surface stays pinned: the `--calibrate` CLI flag exists
  in dryrun AND is documented, the BENCH_* section names CI asserts on
  appear in docs/benchmarking.md, and the plan-lifecycle doc describes the
  Calibration stage the warm-up path actually executes.

Device-free (string checks only), so CI's fast subset runs them.
"""
import os
import re

import pytest

from repro.core import lower
from repro.core.schedule import DATAFLOWS

ROOT = os.path.join(os.path.dirname(__file__), "..")
DATAFLOWS_MD = os.path.join(ROOT, "docs", "dataflows.md")
BENCHMARKING_MD = os.path.join(ROOT, "docs", "benchmarking.md")
LIFECYCLE_MD = os.path.join(ROOT, "docs", "plan-lifecycle.md")
DRYRUN_PY = os.path.join(ROOT, "src", "repro", "launch", "dryrun.py")


def _read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


@pytest.mark.parametrize("name", DATAFLOWS)
def test_every_dataflow_documented(name):
    assert name in _read(DATAFLOWS_MD), (
        f"schedule dataflow {name!r} is missing from docs/dataflows.md — "
        f"document its lowering before shipping it")


@pytest.mark.parametrize("mode", lower.EXEC_MODES)
def test_every_exec_mode_documented(mode):
    assert mode in _read(DATAFLOWS_MD), (
        f"ExecPlan mode {mode!r} is missing from docs/dataflows.md — "
        f"add it to the mode table")


@pytest.mark.parametrize("reason", lower.REASONS)
def test_every_fallback_reason_documented(reason):
    assert reason in _read(DATAFLOWS_MD), (
        f"fallback reason {reason!r} is missing from docs/dataflows.md — "
        f"a degrade report would point users at a doc that never mentions "
        f"it")


# -- calibration surface: CLI flag + artifact schema names stay documented --

def test_calibrate_flag_exists_and_is_documented():
    """`--calibrate` must exist in dryrun's CLI and be documented where the
    lifecycle/benchmarking docs send readers — a renamed flag with stale
    docs is exactly the drift this guard exists for."""
    assert '"--calibrate"' in _read(DRYRUN_PY), (
        "dryrun lost its --calibrate flag; update docs + CI if renamed")
    for doc in (BENCHMARKING_MD, LIFECYCLE_MD):
        assert "--calibrate" in _read(doc), (
            f"{os.path.relpath(doc, ROOT)} no longer documents the "
            f"--calibrate entry point")


@pytest.mark.parametrize("section", [
    "## BENCH_routing.json",
    "## BENCH_calibration.json",
    "## BENCH_tracing.json",
    "## BENCH_analytic.json",
    "## BENCH_kernel.json",
    "## BENCH_serving.json",
    "## BENCH_attention.json",
])
def test_bench_artifact_sections_present(section):
    """CI's assertions reference these artifacts by name; the schema doc
    must keep a section per artifact."""
    assert section in _read(BENCHMARKING_MD), (
        f"docs/benchmarking.md lost its {section!r} section")


@pytest.mark.parametrize("field", [
    # the BENCH_calibration.json keys CI asserts on
    "fit_ok", "rank_agreement", "measured_geomean_ratio", "default_space",
    "step_overhead_s",
])
def test_calibration_schema_fields_documented(field):
    assert field in _read(BENCHMARKING_MD), (
        f"BENCH_calibration.json field {field!r} is asserted by CI but "
        f"missing from docs/benchmarking.md")


# -- observability surface: CLI flags + schema names stay documented --

OBSERVABILITY_MD = os.path.join(ROOT, "docs", "observability.md")
SERVE_PY = os.path.join(ROOT, "src", "repro", "launch", "serve.py")


def test_run_report_flag_exists_and_is_documented():
    """`--run-report` must exist in serve's CLI and be documented where a
    degrade report sends readers (docs/observability.md)."""
    assert '"--run-report"' in _read(SERVE_PY), (
        "serve lost its --run-report flag; update docs + CI if renamed")
    text = _read(OBSERVABILITY_MD)
    for needle in ("--run-report", "--trace"):
        assert needle in text, (
            f"docs/observability.md no longer documents {needle}")


@pytest.mark.parametrize("field", [
    # the run-report keys CI asserts on / launchers render from
    "schema_version", "silent_degrades", "resolve_rate", "dispatches",
    "plan_digest", "calibration_digest", "plan_resolve_us", "provenance",
    # the two-level dispatch contract: every dispatch row carries them
    "inner_kernel", "overlap",
    # the drift-summary keys the staleness decision hangs on
    "profile_stale", "geomean_ratio", "drift_distance",
    "DRIFT_STALE_THRESHOLD",
])
def test_observability_schema_fields_documented(field):
    assert field in _read(OBSERVABILITY_MD), (
        f"run-report/span field {field!r} is part of the observability "
        f"contract but missing from docs/observability.md")


def test_drift_threshold_value_matches_doc():
    """The documented threshold must be the shipped constant."""
    from repro.obs import DRIFT_STALE_THRESHOLD
    assert f"DRIFT_STALE_THRESHOLD = {DRIFT_STALE_THRESHOLD}" in \
        _read(OBSERVABILITY_MD), (
            "docs/observability.md documents a different drift threshold "
            "than obs.drift ships")


def test_plan_lifecycle_documents_calibration_stage():
    text = _read(LIFECYCLE_MD)
    assert "## Calibration" in text
    for needle in ("CalibrationProfile", "fit_ok", "calibration_digest",
                   ".profile.json"):
        assert needle in text, (
            f"docs/plan-lifecycle.md Calibration stage lost {needle!r}")


def test_plan_lifecycle_documents_online_tuning_stage():
    """The online-tuning surface stays pinned: the stage section, the
    `analytic` variant/source string (CI asserts run-report provenance
    against it), the shortlist entry points, and the launcher flags."""
    text = _read(LIFECYCLE_MD)
    assert "## Online (analytic) tuning" in text, (
        "docs/plan-lifecycle.md lost the Online (analytic) tuning stage")
    for needle in ('"analytic"', "analytic_shortlist", "analytic_tune",
                   "BENCH_analytic.json", "--cold-serve",
                   "--no-online-tune"):
        assert needle in text, (
            f"docs/plan-lifecycle.md Online (analytic) tuning stage lost "
            f"{needle!r}")
    # the variant string the docs pin must be the shipped constant
    from repro.deploy.plan import SOURCE_ANALYTIC
    assert SOURCE_ANALYTIC == "analytic"


@pytest.mark.parametrize("field", [
    # the BENCH_analytic.json keys CI asserts on
    "top1_rate", "max_cost_ratio", "mean_gen_us", "max_gen_us",
    "within_bounds", "mini_identity", "mini_calibrated", "pod_identity",
])
def test_analytic_schema_fields_documented(field):
    assert field in _read(BENCHMARKING_MD), (
        f"BENCH_analytic.json field {field!r} is asserted by CI but "
        f"missing from docs/benchmarking.md")


@pytest.mark.parametrize("field", [
    # the BENCH_kernel.json keys CI asserts on
    "local_kernel", "routed_modes", "inner_match_rate", "kernel_pick_rate",
    "geomean_ratio",
])
def test_kernel_schema_fields_documented(field):
    assert field in _read(BENCHMARKING_MD), (
        f"BENCH_kernel.json field {field!r} is asserted by CI but "
        f"missing from docs/benchmarking.md")


# -- serving surface: CLI flags + serving-section schema stay documented --

SERVING_MD = os.path.join(ROOT, "docs", "serving.md")


def test_traffic_flag_exists_and_is_documented():
    """`--traffic` must exist in serve's CLI and be documented where the
    serving doc sends readers — the harness entry point cannot silently
    rename."""
    assert '"--traffic"' in _read(SERVE_PY), (
        "serve lost its --traffic flag; update docs + CI if renamed")
    text = _read(SERVING_MD)
    for needle in ("--traffic", "--traffic-seed", "--batch-mode",
                   "serving_bench.py"):
        assert needle in text, (
            f"docs/serving.md no longer documents {needle}")


@pytest.mark.parametrize("field", [
    # the serving-section keys CI asserts on / the launchers render from
    "goodput_tps", "throughput_tps", "deadline_miss_rate",
    "p50_latency_s", "p99_latency_s", "p50_ttft_s", "p99_ttft_s",
    "cold_shapes", "distinct_shapes", "mean_batch_utilization",
    "resolve_rate", "per_phase", "makespan_s",
])
def test_serving_schema_fields_documented(field):
    assert field in _read(SERVING_MD), (
        f"serving-section field {field!r} is part of the serving contract "
        f"but missing from docs/serving.md")


@pytest.mark.parametrize("field", [
    # the BENCH_serving.json keys CI asserts on
    "goodput_floor", "p99_bound_s", "resolve_floor", "bucket_cold_shapes",
    "bucket_vs_fifo_goodput", "within_bounds", "warmed_pool",
])
def test_serving_bench_schema_fields_documented(field):
    assert field in _read(BENCHMARKING_MD), (
        f"BENCH_serving.json field {field!r} is asserted by CI but "
        f"missing from docs/benchmarking.md")


def test_two_level_schedule_documented():
    """The inner level's surface stays pinned: every InnerKernel field
    name, the Schedule flags, and the VMEM demotion budget appear in the
    dataflows doc's two-level section."""
    import dataclasses as dc

    from repro.core.schedule import InnerKernel
    text = _read(DATAFLOWS_MD)
    for f in dc.fields(InnerKernel):
        assert f.name in text, (
            f"InnerKernel field {f.name!r} missing from docs/dataflows.md")
    for needle in ("inner_kernel", "overlap", "INNER_VMEM_BUDGET",
                   "local_matmul"):
        assert needle in text, (
            f"two-level schedule surface {needle!r} missing from "
            f"docs/dataflows.md")


def _markdown_files():
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                    if f.endswith(".md"))
    return files

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


@pytest.mark.parametrize("md", _markdown_files(),
                         ids=[os.path.relpath(f, ROOT).replace(os.sep, "/")
                              for f in _markdown_files()])
def test_relative_links_resolve(md):
    broken = []
    for target in _LINK.findall(_read(md)):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(md), path))
        if not os.path.exists(resolved):
            broken.append(target)
    assert not broken, (f"{os.path.relpath(md, ROOT)} has broken relative "
                        f"links: {broken}")
