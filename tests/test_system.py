"""End-to-end system tests: every smoke arch trains (loss decreases, no
NaNs), decodes, checkpoints and recovers from injected failures."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft.runtime import FailureInjector, LoopConfig, run_training
from repro.models.model import decode_init, decode_step, forward, init_params
from repro.optim import adamw, compress
from repro.train.steps import make_serve_step, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b, s, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jnp.ones((b, cfg.n_prefix, cfg.d_model),
                                          jnp.bfloat16) * 0.01
    if cfg.is_encoder_decoder:
        batch["encoder_embeds"] = jnp.ones((b, cfg.n_prefix, cfg.d_model),
                                           jnp.bfloat16) * 0.01
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(0)
    batch = _batch_for(cfg, 2, 32, rng)
    kwargs = {}
    if "prefix_embeds" in batch:
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    if "encoder_embeds" in batch:
        kwargs["encoder_embeds"] = batch["encoder_embeds"]
    logits = forward(params, batch["tokens"], cfg, remat=False, **kwargs)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits)).all()


# zamba2's scanned hybrid super-layers are the one smoke train step that
# breaks the 10s budget — it rides the full lane (CI's fast lane runs the
# other nine archs, which cover every other block kind)
@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow if a == "zamba2-1.2b" else [])
    for a in list_archs()])
def test_train_step_runs(arch):
    cfg = smoke_config(arch)
    params = init_params(KEY, cfg)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    ostate = adamw.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    rng = np.random.default_rng(1)
    batch = _batch_for(cfg, 2, 32, rng)
    params2, ostate2, _, metrics = step(params, ostate, None, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                    - b.astype(jnp.float32)).max()),
                         params, params2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ["olmo-1b", "deepseek-moe-16b", "zamba2-1.2b",
                                  "xlstm-1.3b", "seamless-m4t-medium"])
def test_decode_steps(arch):
    cfg = smoke_config(arch)
    params = init_params(KEY, cfg)
    serve = jax.jit(make_serve_step(cfg))
    caches = decode_init(params, cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    kwargs = {}
    if cfg.is_encoder_decoder:
        enc = jnp.ones((2, cfg.n_prefix, cfg.d_model), cfg.dtype) * 0.01
        kwargs = {"encoder_out": enc @ params["frontend_proj"]}
    for i in range(3):
        logits, caches = serve(params, caches, tok, jnp.asarray(i), **kwargs)
        tok = jnp.argmax(logits, -1)[:, None] % cfg.vocab
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_decreases_with_failure_recovery(tmp_path):
    cfg = smoke_config("olmo-1b")
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    params = init_params(KEY, cfg)
    state0 = (params, adamw.init(params), compress.init(params))
    raw = jax.jit(make_train_step(cfg, opt, microbatches=2, compress_grads=True))

    def step_fn(state, batch):
        p, o, c = state
        p, o, c, m = raw(p, o, c, batch)
        return (p, o, c), m

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
    losses = []
    run_training(
        step_fn, state0, data,
        LoopConfig(total_steps=40, ckpt_every=10, ckpt_dir=str(tmp_path)),
        make_batch_arrays=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
        injector=FailureInjector(fail_at={15}),
        on_metrics=lambda s, m: losses.append((s, float(m["loss"]))))
    first = np.mean([l for s, l in losses if s < 5])
    last = np.mean([l for s, l in losses if s >= 35])
    assert last < first - 0.2, f"no learning: {first} -> {last}"
    # failure at 15 was recovered: steps continued past it
    assert max(s for s, _ in losses) == 39


def test_decode_matches_forward_logits():
    """Prefill-then-decode must agree with teacher-forced forward."""
    cfg = smoke_config("olmo-1b")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    full_logits = forward(params, tokens, cfg, remat=False)
    caches = decode_init(params, cfg, 2, 16)
    for i in range(tokens.shape[1]):
        logits, caches = decode_step(params, caches, tokens[:, i:i + 1],
                                     jnp.asarray(i), cfg)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)
