"""Property-based invariants of the SoftHier performance model
(sim/perf.py) — the contracts the measured-calibration layer relies on:

- **superstep max semantics**: a report's total is the sum over supersteps
  of max(compute, comm) plus barriers, so `total_time >= max(compute_time,
  dma_time, noc_time, barrier_time)` for every legal schedule — this is
  what makes the calibration's clamped rescale (`PerfReport.calibrated`)
  safe for any non-negative scale combination;
- **monotonicity**: more work can never be predicted faster — growing K
  (more K-chunks per tile) or the macro-iteration tile counts (more grid
  sweeps) must not decrease the predicted total;
- **round-trip exactness**: `PerfReport.to_dict/from_dict` is the identity
  (bit-exact floats), including the `calibration` provenance field the
  plan schema persists.

Device-free: schedule building and pricing never touch jax.
"""
import dataclasses

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.sim.perf import PerfReport, estimate

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))

pow2 = lambda lo, hi: st.sampled_from(
    [1 << i for i in range(lo.bit_length() - 1, hi.bit_length())])

# legal-by-construction schedule space on the 4x4 MINI grid: dimensions are
# multiples of the grid factors, tk drawn from the tuner's own menu
schedules = st.fixed_dictionaries({
    "m": pow2(64, 512),
    "n": pow2(64, 512),
    "k": pow2(64, 2048),
    "gm": st.sampled_from([1, 2, 4]),
    "tk": st.sampled_from([64, 128, 256]),
    "dataflow": st.sampled_from(["summa", "systolic", "splitk_summa",
                                 "baseline"]),
    "gk": st.sampled_from([1, 2, 4]),
    "stages": st.sampled_from([1, 4]),
})


def build(p, m=None, k=None, iter_m=1):
    m = m if m is not None else p["m"]
    k = k if k is not None else p["k"]
    gk = p["gk"] if p["dataflow"] == "splitk_summa" else 1
    rest = 16 // gk
    gm = min(p["gm"], rest)
    gn = rest // gm
    if p["dataflow"] == "systolic" and (gm == 1 or gn == 1):
        gm = gn = None  # caller skips
    if gm is None:
        return None
    shape = GEMMShape(m * iter_m, n=p["n"], k=k)
    if shape.m % (gm * iter_m) or shape.n % gn or shape.k % gk:
        return None
    sched = Schedule(shape, Tiling(gm, gn, gk, iter_m=iter_m, tk=p["tk"]),
                     p["dataflow"], store_stages=p["stages"], elem_bytes=4)
    try:
        return build_program(sched, MINI)
    except (ValueError, KeyError):
        return None


@given(p=schedules)
@settings(max_examples=60, deadline=None)
def test_total_time_dominates_every_resource(p):
    prog = build(p)
    if prog is None:
        return
    rep = estimate(prog, MINI)
    assert rep.total_time >= rep.compute_time - 1e-12
    assert rep.total_time >= rep.dma_time - 1e-12
    assert rep.total_time >= rep.noc_time - 1e-12
    assert rep.total_time >= rep.barrier_time - 1e-12
    assert rep.total_time > 0.0
    shares = rep.resource_shares()
    assert all(s >= 0.0 for s in shares)
    assert sum(shares) == pytest.approx(1.0)


@given(p=schedules)
@settings(max_examples=40, deadline=None)
def test_monotone_in_k(p):
    small, big = build(p), build(p, k=2 * p["k"])
    if small is None or big is None:
        return
    t_small = estimate(small, MINI).total_time
    t_big = estimate(big, MINI).total_time
    assert t_big >= t_small - 1e-12, (
        f"doubling K reduced predicted time: {t_small} -> {t_big}")


@given(p=schedules)
@settings(max_examples=40, deadline=None)
def test_monotone_in_tile_count(p):
    """More macro-iterations (the grid sweeping a bigger M) can never be
    predicted faster than the single-coverage problem."""
    small, big = build(p, iter_m=1), build(p, iter_m=2)
    if small is None or big is None:
        return
    t_small = estimate(small, MINI).total_time
    t_big = estimate(big, MINI).total_time
    assert t_big >= t_small - 1e-12, (
        f"doubling the M tile count reduced predicted time: "
        f"{t_small} -> {t_big}")


reports = st.builds(
    PerfReport,
    total_time=st.floats(0, 1e3, allow_nan=False),
    compute_time=st.floats(0, 1e3, allow_nan=False),
    dma_time=st.floats(0, 1e3, allow_nan=False),
    noc_time=st.floats(0, 1e3, allow_nan=False),
    barrier_time=st.floats(0, 1e3, allow_nan=False),
    total_flops=st.integers(0, 1 << 50),
    hbm_bytes=st.integers(0, 1 << 40),
    noc_bytes=st.integers(0, 1 << 40),
    n_supersteps=st.integers(0, 1 << 20),
    calibration=st.sampled_from(["", "a53c52d7174b", "deadbeef0123"]),
)


@given(rep=reports)
@settings(max_examples=100, deadline=None)
def test_report_round_trip_is_exact(rep):
    back = PerfReport.from_dict(rep.to_dict())
    assert back == rep                       # bit-exact, calibration included
    assert dataclasses.asdict(back) == dataclasses.asdict(rep)
