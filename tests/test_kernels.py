"""Per-kernel validation: shape/dtype sweeps of the Pallas kernels in
interpret mode against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref
from repro.kernels.mmad import mmad
from repro.kernels.ops import pick_block_shape, tile_matmul

RNG = np.random.default_rng(42)


def _mk(m, k, n, dtype):
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype=dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype=dtype)
    return a, b


TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
@pytest.mark.parametrize("shape", [
    (128, 128, 128), (256, 128, 128), (128, 384, 256), (256, 256, 512),
])
def test_mmad_shape_sweep(shape, dtype):
    m, k, n = shape
    a, b = _mk(m, k, n, dtype)
    out = mmad(a, b, block_shape=(128, 128, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.mmad_ref(a, b), np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("bs", [(128, 128, 128), (64, 128, 128), (128, 256, 64)])
def test_mmad_block_shapes(bs):
    m = 2 * bs[0]
    n = 2 * bs[1]
    k = 2 * bs[2]
    a, b = _mk(m, k, n, jnp.float32)
    out = mmad(a, b, block_shape=bs, interpret=True)
    np.testing.assert_allclose(out, ref.mmad_ref(a, b), rtol=1e-4, atol=1e-4)


def test_mmad_out_dtype():
    a, b = _mk(128, 128, 128, jnp.bfloat16)
    out = mmad(a, b, interpret=True, out_dtype=jnp.float32)
    assert out.dtype == jnp.float32


def test_mmad_rejects_ragged():
    a, b = _mk(100, 128, 128, jnp.float32)
    with pytest.raises(ValueError, match="not divisible"):
        mmad(a, b, block_shape=(128, 128, 128), interpret=True)


@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300))
@settings(max_examples=12, deadline=None)
def test_tile_matmul_padding_property(m, k, n):
    """tile_matmul must agree with the oracle for ANY shape (pads internally)."""
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype=jnp.float32)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype=jnp.float32)
    out = tile_matmul(a, b, interpret=True, use_kernel=True)
    np.testing.assert_allclose(out, a @ b, rtol=1e-3, atol=1e-3)


def test_pick_block_shape_alignment():
    bm, bn, bk = pick_block_shape(4096, 4096, 4096, elem_bytes=2)
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
    # double-buffered working set fits the budget
    assert (bm * bk + bk * bn) * 2 * 2 + bm * bn * 4 <= 8 * 1024 * 1024


def test_splitk_ref_matches_dense():
    a, b = _mk(64, 256, 64, jnp.float32)
    np.testing.assert_allclose(ref.splitk_ref(a, b, splits=4),
                               ref.mmad_ref(a, b), rtol=1e-4, atol=1e-4)


def test_flash_attention_ref_causal():
    q = jnp.asarray(RNG.standard_normal((2, 16, 8)), dtype=jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 16, 8)), dtype=jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 16, 8)), dtype=jnp.float32)
    out = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.shape == q.shape
    # first query position attends only to itself
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-5)
