"""Per-kernel validation: shape/dtype sweeps of the Pallas kernels in
interpret mode against the pure-jnp oracles, plus the `local_matmul`
parity contract the mesh dataflows rely on: on CPU the schedule-resolved
local GEMM is BITWISE the `jnp.dot` fp32 oracle (routing through the
kernel funnel must not move routed numerics on this host), casts never
narrow the data, and gradients flow through the custom_vjp.

The property-based tests need hypothesis (requirements-dev.txt); the
parity and contract tests run without it so the local fast lane still
covers the dispatch path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedule import InnerKernel
from repro.kernels import ref
from repro.kernels.mmad import mmad
from repro.kernels.ops import (_VMEM_BUDGET, local_matmul, pick_block_shape,
                               tile_matmul)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # CI installs requirements-dev; local lane may not
    HAVE_HYPOTHESIS = False

RNG = np.random.default_rng(42)


def _mk(m, k, n, dtype):
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype=dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype=dtype)
    return a, b


TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
@pytest.mark.parametrize("shape", [
    (128, 128, 128), (256, 128, 128), (128, 384, 256), (256, 256, 512),
])
def test_mmad_shape_sweep(shape, dtype):
    m, k, n = shape
    a, b = _mk(m, k, n, dtype)
    out = mmad(a, b, block_shape=(128, 128, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.mmad_ref(a, b), np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("bs", [(128, 128, 128), (64, 128, 128), (128, 256, 64)])
def test_mmad_block_shapes(bs):
    m = 2 * bs[0]
    n = 2 * bs[1]
    k = 2 * bs[2]
    a, b = _mk(m, k, n, jnp.float32)
    out = mmad(a, b, block_shape=bs, interpret=True)
    np.testing.assert_allclose(out, ref.mmad_ref(a, b), rtol=1e-4, atol=1e-4)


def test_mmad_out_dtype():
    a, b = _mk(128, 128, 128, jnp.bfloat16)
    out = mmad(a, b, interpret=True, out_dtype=jnp.float32)
    assert out.dtype == jnp.float32


def test_mmad_rejects_ragged():
    a, b = _mk(100, 128, 128, jnp.float32)
    with pytest.raises(ValueError, match="not divisible"):
        mmad(a, b, block_shape=(128, 128, 128), interpret=True)


# ---------------------------------------------------------------------------
# local_matmul: the schedule-resolved per-device GEMM
# ---------------------------------------------------------------------------

def _oracle(a, b):
    """The exact expression the mesh dataflows used before routing was
    kernel-aware — the bitwise bar for the CPU path."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


KER32 = InnerKernel(128, 128, 128, dtype="float32")


def test_local_matmul_cpu_bitwise_oracle():
    """On CPU (non-interpret) the kernel path IS the oracle, bit for bit —
    enabling inner kernels cannot move routed numerics on this host."""
    a, b = _mk(192, 256, 160, jnp.float32)
    out = local_matmul(a, b, KER32)
    assert out.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(_oracle(a, b)))


@pytest.mark.parametrize("dtype_name,jdt,tol", [
    ("float32", jnp.float32, 1e-4),
    ("bfloat16", jnp.bfloat16, 1e-2),
    ("float8_e4m3", jnp.float8_e4m3fn, 1e-2),
], ids=["f32", "bf16", "fp8"])
def test_local_matmul_interpret_matches_oracle(dtype_name, jdt, tol):
    """interpret=True runs the real Pallas mmad at the kernel's geometry;
    products of the (already-quantized) operands are exact in the fp32
    accumulator, so only accumulation order separates it from the oracle.
    Ragged shape exercises the padding path."""
    a32, b32 = _mk(160, 192, 224, jnp.float32)
    a, b = a32.astype(jdt), b32.astype(jdt)
    kernel = InnerKernel(128, 128, 128, dtype=dtype_name)
    out = local_matmul(a, b, kernel, True)
    want = _oracle(a.astype(jnp.float32), b.astype(jnp.float32))
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


def test_local_matmul_never_downcasts():
    """An fp8 kernel on fp32 data must NOT quantize — precision is the
    model's call, not the scheduler's. Output stays bitwise the oracle."""
    a, b = _mk(128, 256, 128, jnp.float32)
    kernel = InnerKernel(128, 128, 128, dtype="float8_e4m3")
    np.testing.assert_array_equal(np.asarray(local_matmul(a, b, kernel)),
                                  np.asarray(_oracle(a, b)))


def test_local_matmul_no_float_int_crossing():
    """An int8 kernel on fp8 data would reinterpret values (equal byte
    width, different kind) — the cast must refuse."""
    a32, b32 = _mk(128, 128, 128, jnp.float32)
    a, b = a32.astype(jnp.float8_e4m3fn), b32.astype(jnp.float8_e4m3fn)
    kernel = InnerKernel(128, 128, 128, dtype="int8")
    np.testing.assert_array_equal(np.asarray(local_matmul(a, b, kernel)),
                                  np.asarray(_oracle(a, b)))


def test_local_matmul_widening_cast():
    """bf16 data on an fp32 kernel widens (always safe) before the dot."""
    a32, b32 = _mk(64, 128, 64, jnp.float32)
    a, b = a32.astype(jnp.bfloat16), b32.astype(jnp.bfloat16)
    out = local_matmul(a, b, KER32)
    want = _oracle(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_local_matmul_grad_parity():
    """The custom_vjp's transposed fp32 matmuls agree with autodiff of the
    oracle — routed training through the kernel path stays correct."""
    a, b = _mk(96, 128, 80, jnp.float32)

    def loss_kernel(x, y):
        return (local_matmul(x, y, KER32) ** 2).sum()

    def loss_oracle(x, y):
        return (_oracle(x, y) ** 2).sum()

    ga_k, gb_k = jax.grad(loss_kernel, argnums=(0, 1))(a, b)
    ga_o, gb_o = jax.grad(loss_oracle, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_k), np.asarray(ga_o),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb_k), np.asarray(gb_o),
                               rtol=1e-5, atol=1e-5)
    assert ga_k.dtype == a.dtype and gb_k.dtype == b.dtype


def test_inner_kernel_roundtrip_and_budget():
    ik = InnerKernel(128, 128, 512, depth=1, dtype="bfloat16")
    assert InnerKernel.from_dict(ik.to_dict()) == ik
    assert ik.describe() == "128x128x512d1:bfloat16"
    assert ik.working_set_bytes() <= _VMEM_BUDGET
    big = InnerKernel(2048, 2048, 2048, dtype="float32")
    assert big.working_set_bytes() > _VMEM_BUDGET


# ---------------------------------------------------------------------------
# pick_block_shape: the VMEM-budget / divisibility contract
# ---------------------------------------------------------------------------

def _check_block_contract(m, n, k, eb):
    bm, bn, bk = pick_block_shape(m, n, k, eb)
    kp = -(-k // 128) * 128
    assert bm % 8 == 0 and bn % 128 == 0, (bm, bn)
    # bk always divides the 128-padded K — tile_matmul's padding stays at
    # the explicit 128 alignment, never silently bk-sized
    assert 1 <= bk <= kp and kp % bk == 0, (bk, kp)
    ws = (bm * bk + bk * bn) * eb * 2 + bm * bn * 4
    assert ws <= _VMEM_BUDGET, (bm, bn, bk, ws)


@pytest.mark.parametrize("m,n,k,eb", [
    (1, 1, 1, 4), (8, 128, 127, 2), (100, 300, 129, 1),
    (4096, 4096, 4096, 4), (128, 128, 1 << 20, 2), (7, 9, 999, 4),
    (128, 128, 384, 1),  # kp not a power of two: bk must still divide it
])
def test_pick_block_shape_contract(m, n, k, eb):
    _check_block_contract(m, n, k, eb)


def test_pick_block_shape_alignment():
    bm, bn, bk = pick_block_shape(4096, 4096, 4096, elem_bytes=2)
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
    # double-buffered working set fits the budget
    assert (bm * bk + bk * bn) * 2 * 2 + bm * bn * 4 <= 8 * 1024 * 1024


def test_splitk_ref_matches_dense():
    a, b = _mk(64, 256, 64, jnp.float32)
    np.testing.assert_allclose(ref.splitk_ref(a, b, splits=4),
                               ref.mmad_ref(a, b), rtol=1e-4, atol=1e-4)


def test_flash_attention_ref_causal():
    q = jnp.asarray(RNG.standard_normal((2, 16, 8)), dtype=jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 16, 8)), dtype=jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 16, 8)), dtype=jnp.float32)
    out = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.shape == q.shape
    # first query position attends only to itself
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# property tests (hypothesis, requirements-dev.txt)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300))
    @settings(max_examples=12, deadline=None)
    def test_tile_matmul_padding_property(m, k, n):
        """tile_matmul must agree with the oracle for ANY shape (pads
        internally)."""
        a = jnp.asarray(RNG.standard_normal((m, k)), dtype=jnp.float32)
        b = jnp.asarray(RNG.standard_normal((k, n)), dtype=jnp.float32)
        out = tile_matmul(a, b, interpret=True, use_kernel=True)
        np.testing.assert_allclose(out, a @ b, rtol=1e-3, atol=1e-3)

    @given(m=st.integers(1, 8192), n=st.integers(1, 8192),
           k=st.integers(1, 1 << 16), eb=st.sampled_from([1, 2, 4]))
    @settings(max_examples=60, deadline=None)
    def test_pick_block_shape_property(m, n, k, eb):
        """For ANY problem shape and element width: MXU alignment, bk
        divides the 128-padded K, and the double-buffered working set
        stays under the VMEM budget."""
        _check_block_contract(m, n, k, eb)

else:  # keep the skip visible in local runs without hypothesis

    @pytest.mark.skip(reason="property tests need hypothesis "
                      "(requirements-dev.txt)")
    def test_property_suite_needs_hypothesis():
        pass
