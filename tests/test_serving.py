"""Serving harness: the traffic replay's contracts stay mechanical.

- seeded trace generation is deterministic (same seed -> same trace) and
  per-tenant isolated (adding a tenant never perturbs another's arrivals);
- continuous-batcher invariants: every submitted request is admitted
  exactly once, admission order within a tenant is arrival order, the
  tenant with the oldest waiting head is always served next (no
  starvation), and bucket-mode admission ages out at `max_wait_s`;
- SLO accounting arithmetic on hand-built request records;
- the virtual-clock replay completes every request, charges cold shapes
  exactly once, and produces an identical serving section on re-run;
- hypothesis properties (function-scoped guard, same pattern as
  test_analytic.py): bucket-aware admission never emits a batch whose M is
  outside the warmed pow-2 pool; request conservation under arbitrary
  submit/drain interleavings;
- a slow multidevice subprocess proof: `serve --traffic` on routed
  gemma-2b emits a run report with resolve_rate 1.0, zero silent
  degrades, and a serving section with nonzero goodput + per-phase hit
  rates (the ISSUE's production-traffic claim, asserted end-to-end).
"""
import json
import math
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import pytest

from repro.deploy.batcher import (BatchPolicy, ContinuousBatcher, Request,
                                  bucket_pool, decode_m)
from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                             TileConfig)
from repro.launch.traffic import (RequestRecord, ServingCosts, TenantSpec,
                                  TrafficConfig, generate_trace,
                                  serving_section, simulate, slo_summary)

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))

STUB_CFG = SimpleNamespace(
    name="stub", d_model=64, hd=16, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=1000, attn="gqa", n_experts=0, moe_top_k=0, moe_d_ff=0,
    q_lora_rank=0, kv_lora_rank=0, rope_head_dim=0, nope_head_dim=0)


class StubPlanner:
    """plan_cached stub: pow-2 M serves a tuned hit, ragged M an analytic
    plan, and M in `unplanned` returns None (the fallback path). Cost is a
    deterministic function of the shape, so replays are reproducible."""

    def __init__(self, unplanned=()):
        self.hw = MINI
        self.elem_bytes = 4
        self.unplanned = set(unplanned)
        self.lookups = 0

    def plan_cached(self, shape):
        self.lookups += 1
        if shape.m in self.unplanned:
            return None
        source = "tuned" if shape.m & (shape.m - 1) == 0 else "analytic"
        return SimpleNamespace(
            source=source,
            report=SimpleNamespace(total_time=1e-6 * shape.m + 1e-5))


def _traffic(seed=3, n=12):
    return TrafficConfig(seed=seed, tenants=(
        TenantSpec(name="a", rate_rps=300.0, n_requests=n,
                   prompt_lens=(5, 9, 13), gen_lens=(1, 2, 3)),
        TenantSpec(name="b", rate_rps=200.0, n_requests=n,
                   prompt_lens=(7, 11), gen_lens=(1, 2)),
    ))


def _req(rid, tenant="a", arrival=0.0, prompt=8, gen=2, slo=math.inf):
    return Request(rid=rid, tenant=tenant, arrival_s=arrival,
                   prompt_len=prompt, gen_len=gen, slo_s=slo)


# ---------------------------------------------------------------------------
# seeded trace generation
# ---------------------------------------------------------------------------

def _key(r):
    return (r.rid, r.tenant, r.arrival_s, r.prompt_len, r.gen_len, r.slo_s)


def test_generate_trace_deterministic():
    a = [_key(r) for r in generate_trace(_traffic(seed=3))]
    b = [_key(r) for r in generate_trace(_traffic(seed=3))]
    assert a == b
    c = [_key(r) for r in generate_trace(_traffic(seed=4))]
    assert a != c


def test_generate_trace_tenant_isolation():
    """Adding a tenant must not perturb another tenant's stream — each
    tenant draws from its own seeded RNG."""
    solo = TrafficConfig(seed=3, tenants=(_traffic().tenants[0],))
    both = _traffic(seed=3)
    solo_a = [(r.arrival_s, r.prompt_len, r.gen_len)
              for r in generate_trace(solo) if r.tenant == "a"]
    both_a = [(r.arrival_s, r.prompt_len, r.gen_len)
              for r in generate_trace(both) if r.tenant == "a"]
    assert solo_a == both_a


def test_generate_trace_sorted_and_bounded():
    trace = generate_trace(_traffic())
    assert [r.rid for r in trace] == list(range(len(trace)))
    arrivals = [r.arrival_s for r in trace]
    assert arrivals == sorted(arrivals)
    for r in trace:
        spec = {"a": _traffic().tenants[0], "b": _traffic().tenants[1]}
        assert r.prompt_len in spec[r.tenant].prompt_lens
        assert r.gen_len in spec[r.tenant].gen_lens
        assert r.slo_s == (spec[r.tenant].slo_ttft_s
                           + r.gen_len * spec[r.tenant].slo_per_token_s)


# ---------------------------------------------------------------------------
# batcher invariants
# ---------------------------------------------------------------------------

def _drain(batcher, now=1e9):
    batches = []
    while True:
        b = batcher.next_prefill(now)
        if b is None:
            break
        batches.append(b)
    return batches


@pytest.mark.parametrize("mode", ["bucket", "fifo"])
def test_batcher_conservation_and_fifo_order(mode):
    batcher = ContinuousBatcher(BatchPolicy(mode=mode))
    reqs = [_req(i, tenant="ab"[i % 2], arrival=0.001 * i, prompt=5 + i)
            for i in range(13)]
    for r in reqs:
        batcher.submit(r)
    batches = _drain(batcher)
    admitted = [r.rid for b in batches for r in b.requests]
    assert sorted(admitted) == [r.rid for r in reqs]     # exactly once
    assert batcher.pending() == 0
    assert batcher.admitted == batcher.submitted == len(reqs)
    for tenant in ("a", "b"):
        order = [r.rid for b in batches for r in b.requests
                 if r.tenant == tenant]
        assert order == sorted(order), "FIFO order broken within tenant"


def test_batcher_no_starvation_oldest_head_first():
    batcher = ContinuousBatcher(BatchPolicy(mode="bucket"))
    # tenant b's lone request is OLDER than tenant a's flood
    batcher.submit(_req(0, tenant="b", arrival=0.0, prompt=3))
    for i in range(1, 9):
        batcher.submit(_req(i, tenant="a", arrival=0.5, prompt=16))
    first = batcher.next_prefill(now=10.0)
    assert first.tenant == "b" and first.requests[0].rid == 0


def test_bucket_admission_waits_then_ages_out():
    policy = BatchPolicy(mode="bucket", min_fill=0.75, max_wait_s=0.05)
    batcher = ContinuousBatcher(policy)
    batcher.submit(_req(0, arrival=1.0, prompt=5))   # 5/8 = 0.625 < 0.75
    assert batcher.next_prefill(now=1.0) is None     # waits for fill
    assert batcher.next_decision_s() == pytest.approx(1.05)
    aged = batcher.next_prefill(now=1.05)            # aging bound reached
    assert aged is not None and aged.rows == 5 and aged.m == 8


def test_bucket_admission_prefers_best_fill():
    """6+2 fills the 8-bucket exactly; the third request would spill to 16
    at 11/16 fill — admission stops at the full bucket."""
    batcher = ContinuousBatcher(BatchPolicy(mode="bucket"))
    for i, p in enumerate((6, 2, 3)):
        batcher.submit(_req(i, arrival=0.0, prompt=p))
    batch = batcher.next_prefill(now=0.0)
    assert [r.rid for r in batch.requests] == [0, 1]
    assert batch.rows == 8 and batch.m == 8 and batch.utilization == 1.0


def test_fifo_admission_is_exact_and_immediate():
    batcher = ContinuousBatcher(BatchPolicy(mode="fifo"))
    batcher.submit(_req(0, arrival=0.0, prompt=5))
    batch = batcher.next_prefill(now=0.0)             # no waiting
    assert batch.rows == 5 and batch.m == 5           # no padding


def test_decode_m_and_bucket_pool():
    bucket = BatchPolicy(mode="bucket")
    fifo = BatchPolicy(mode="fifo")
    assert decode_m(3, bucket) == 4 and decode_m(3, fifo) == 3
    assert decode_m(8, bucket) == 8
    assert bucket_pool(40, bucket) == [1, 2, 4, 8, 16, 32, 64]
    assert bucket.bucket_m(10 ** 9) == bucket.dim_cap  # saturates at cap


def test_batch_policy_validation():
    with pytest.raises(ValueError):
        BatchPolicy(mode="lifo")
    with pytest.raises(ValueError):
        BatchPolicy(min_fill=0.0)


# ---------------------------------------------------------------------------
# SLO accounting arithmetic
# ---------------------------------------------------------------------------

def test_slo_summary_hand_built():
    recs = [
        RequestRecord(rid=0, tenant="a", arrival_s=0.0, prompt_len=10,
                      gen_len=2, slo_s=1.0, ttft_s=0.1, done_s=0.5),   # met
        RequestRecord(rid=1, tenant="a", arrival_s=1.0, prompt_len=4,
                      gen_len=1, slo_s=0.2, ttft_s=0.1, done_s=1.5),   # miss
        RequestRecord(rid=2, tenant="b", arrival_s=0.0, prompt_len=6,
                      gen_len=4, slo_s=2.0, ttft_s=0.3, done_s=2.0),   # met
    ]
    s = slo_summary(recs, makespan_s=2.0)
    assert s["requests"] == 3 and s["met"] == 2 and s["missed"] == 1
    assert s["deadline_miss_rate"] == pytest.approx(1 / 3)
    assert s["good_tokens"] == 12 + 10 and s["total_tokens"] == 27
    assert s["goodput_tps"] == pytest.approx(22 / 2.0)
    assert s["throughput_tps"] == pytest.approx(27 / 2.0)


def test_slo_summary_empty():
    s = slo_summary([], makespan_s=0.0)
    assert s["requests"] == 0 and s["deadline_miss_rate"] == 0.0
    assert s["goodput_tps"] == 0.0


# ---------------------------------------------------------------------------
# virtual-clock replay against the stub planner
# ---------------------------------------------------------------------------

def _simulate(mode="bucket", planner=None, trace=None, **kw):
    trace = generate_trace(_traffic()) if trace is None else trace
    return simulate(trace, planner or StubPlanner(),
                    {"a": STUB_CFG, "b": STUB_CFG},
                    policy=BatchPolicy(mode=mode), **kw)


@pytest.mark.parametrize("mode", ["bucket", "fifo"])
def test_simulate_completes_every_request(mode):
    trace = generate_trace(_traffic())
    result = _simulate(mode=mode, trace=trace)
    assert len(result.records) == len(trace)
    for rec in result.records:
        assert math.isfinite(rec.ttft_s) and rec.ttft_s >= 0
        assert math.isfinite(rec.done_s)
        assert rec.latency_s >= rec.ttft_s > 0 or rec.gen_len == 0
    assert result.makespan_s >= max(r.done_s for r in result.records) - 1e-12
    assert result.batches > 0 and result.dispatches > 0


def test_simulate_deterministic_section():
    assert serving_section(_simulate()) == serving_section(_simulate())


def test_simulate_first_encounter_charges_once():
    """Cold shapes pay the virtual compile exactly once; a fully
    precompiled pool pays none and finishes strictly earlier."""
    from repro.deploy.planner import model_workload
    trace = generate_trace(_traffic())
    cold = _simulate(trace=trace)
    assert cold.cold_shapes == cold.distinct_shapes > 0
    pool = []
    for m in range(1, 200):
        pool += model_workload(STUB_CFG, batch=m, seq=1, kind="prefill")
        pool += model_workload(STUB_CFG, batch=m, seq=1, kind="decode")
    warm = _simulate(trace=trace, precompiled=pool)
    assert warm.cold_shapes == 0
    assert warm.makespan_s < cold.makespan_s


def test_simulate_fallback_pays_penalty_and_counts():
    """Unplanned shapes land in the fallback tally and the resolve rate
    drops below 1 — raggedness must be visible, never silent."""
    trace = [_req(0, arrival=0.0, prompt=8, gen=1)]
    ok = _simulate(trace=trace, planner=StubPlanner())
    assert ok.resolve_rate == 1.0
    # every decode/prefill M this 1-request trace emits is unplanned
    bad = _simulate(trace=trace,
                    planner=StubPlanner(unplanned={1, 8}))
    assert bad.resolve_rate < 1.0
    assert sum(c["fallback"] for c in bad.per_phase.values()) > 0
    # the penalty multiplies the roofline floor on the virtual clock
    dear = _simulate(trace=trace, planner=StubPlanner(unplanned={1, 8}),
                     costs=ServingCosts(fallback_penalty=1e4))
    assert dear.makespan_s > bad.makespan_s


def test_simulate_dispatch_hook_fires_once_per_shape():
    seen = []
    result = _simulate(dispatch=lambda shape, phase: seen.append(shape))
    assert len(seen) == len(set(seen)) == result.distinct_shapes


def test_serving_section_schema():
    section = serving_section(_simulate())
    for key in ("policy", "requests", "met", "missed", "deadline_miss_rate",
                "good_tokens", "total_tokens", "goodput_tps",
                "throughput_tps", "p50_latency_s", "p99_latency_s",
                "p50_ttft_s", "p99_ttft_s", "makespan_s", "batches",
                "cold_shapes", "distinct_shapes", "mean_batch_utilization",
                "resolve_rate", "per_phase"):
        assert key in section, key
    json.dumps(section)                           # report-embeddable
    for phase in ("prefill", "decode"):
        sub = section["per_phase"][phase]
        assert {"hit", "bucketed", "analytic", "fallback", "dispatches",
                "hit_rate", "resolve_rate"} <= set(sub)
    assert section["goodput_tps"] <= section["throughput_tps"] + 1e-9
    assert section["p50_latency_s"] <= section["p99_latency_s"] + 1e-12


# ---------------------------------------------------------------------------
# hypothesis properties (function-scoped guard: the non-property tests in
# this module must still run without hypothesis installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False


if _HAS_HYPOTHESIS:
    _prompts = st.lists(st.integers(min_value=1, max_value=300),
                        min_size=1, max_size=24)

    @given(prompts=_prompts, max_batch=st.integers(1, 12))
    @settings(max_examples=80, deadline=None)
    def test_bucket_admission_stays_in_pool(prompts, max_batch):
        """Every bucket-mode batch's M is the padded pow-2 of its rows and
        a member of the warmed pool — admission never emits a GEMM the
        harness didn't pre-tune."""
        policy = BatchPolicy(mode="bucket", max_batch=max_batch)
        pool = set(bucket_pool(max_batch * max(prompts), policy))
        batcher = ContinuousBatcher(policy)
        for i, p in enumerate(prompts):
            batcher.submit(_req(i, arrival=0.0, prompt=p))
        for batch in _drain(batcher):
            assert batch.m == policy.bucket_m(batch.rows)
            assert batch.m in pool, (batch.m, sorted(pool))
            assert 0 < batch.utilization <= 1.0
            assert len(batch.requests) <= max_batch

    @given(plan=st.lists(st.tuples(st.booleans(),
                                   st.integers(0, 2),     # tenant index
                                   st.integers(1, 64)),   # prompt len
                         min_size=1, max_size=60),
           mode=st.sampled_from(["bucket", "fifo"]))
    @settings(max_examples=80, deadline=None)
    def test_conservation_under_interleavings(plan, mode):
        """Arbitrary submit/drain interleavings: at drain-out, every
        submitted rid was admitted exactly once, in FIFO order per
        tenant."""
        batcher = ContinuousBatcher(BatchPolicy(mode=mode))
        admitted, rid, now = [], 0, 0.0
        for drain_now, tenant, prompt in plan:
            now += 0.001
            batcher.submit(_req(rid, tenant=f"t{tenant}", arrival=now,
                                prompt=prompt))
            rid += 1
            if drain_now:
                b = batcher.next_prefill(now)
                if b is not None:
                    admitted += [r.rid for r in b.requests]
        admitted += [r.rid for b in _drain(batcher) for r in b.requests]
        assert sorted(admitted) == list(range(rid))
        assert batcher.pending() == 0
        # FIFO within tenant: rids are assigned in arrival order, so each
        # tenant's admitted positions must be increasing
        position = {r: i for i, r in enumerate(admitted)}
        for t_idx in {t for _, t, _ in plan}:
            rids = [r for r, (_, t, _) in enumerate(plan) if t == t_idx]
            positions = [position[r] for r in rids]
            assert positions == sorted(positions)
else:
    def test_bucket_admission_stays_in_pool():
        pytest.importorskip("hypothesis")

    def test_conservation_under_interleavings():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# the end-to-end proof: serve --traffic on a routed multidevice mesh
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

TRAFFIC_BODY = textwrap.dedent("""
    import json
    import subprocess
    import sys

    out = sys.argv[1]
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma-2b",
         "--smoke", "--traffic", "--traffic-requests", "6",
         "--traffic-tenants", "2", "--traffic-seed", "11",
         "--plan-candidates", "4", "--plan-cache", out + "/cache",
         "--run-report", out + "/run_report.json",
         "--trace", out + "/trace.json"],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]

    r = json.load(open(out + "/run_report.json"))
    assert r["schema_version"] == 1 and r["launcher"] == "serve"
    routing = r["routing"]
    assert routing["calls"] > 0
    assert routing["calls"] == routing["routed"], routing
    assert routing["unrouted"] == 0 and routing["resolve_rate"] == 1.0
    assert routing["silent_degrades"] == 0, routing
    s = r["serving"]
    assert s["policy"] == "bucket"
    assert s["requests"] == 12 and s["met"] + s["missed"] == 12
    assert s["goodput_tps"] > 0 and s["throughput_tps"] > 0
    assert s["cold_shapes"] == 0, s            # admission stayed on pool
    assert 0 < s["p50_latency_s"] <= s["p99_latency_s"]
    for phase in ("prefill", "decode"):
        sub = s["per_phase"][phase]
        assert sub["dispatches"] > 0, s["per_phase"]
        assert sub["resolve_rate"] == 1.0, sub
        assert sub["hit_rate"] == 1.0, sub     # warmed pool: pure hits
    assert r["traffic"]["batch_mode"] == "bucket"
    # every pmm dispatch the replay executed carries full provenance
    assert r["dispatches"], "no pmm spans recorded"
    for d in r["dispatches"]:
        assert d["provenance"] in ("hit", "bucketed", "analytic",
                                   "fallback"), d
        assert d["tag"].startswith("traffic."), d
    # the trace has one marker per completed request
    t = json.load(open(out + "/trace.json"))
    marks = [e for e in t["traceEvents"]
             if e.get("name") == "serve.request"]
    assert len(marks) == 12, len(marks)
    assert all("latency_s" in m["args"] for m in marks)
    # the serving line renders from the same dict the report persists
    assert "serving [bucket]:" in proc.stdout
    print("ALL_OK")
""")


@pytest.mark.slow
def test_serve_traffic_multidevice(tmp_path):
    """Replayed mixed prefill/decode load on a routed multidevice gemma-2b
    serve: complete run report with serving section, 100% plan resolution,
    zero silent degrades."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    proc = subprocess.run(
        [sys.executable, "-c", TRAFFIC_BODY, str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout
