"""Layer-level numerics: flash custom_vjp vs dense oracle, MLA absorbed form,
SSD chunked-vs-recurrent consistency, mLSTM chunkwise-vs-recurrent, decode
caches vs teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.models import ssm
from repro.models.attention import _sdpa, chunked_sdpa, mla_attention, mla_params
from repro.models.common import ModelConfig

RNG = np.random.default_rng(0)


def _arr(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


@given(s=st.sampled_from([128, 256, 384]), hkv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), causal=st.booleans())
@settings(max_examples=10, deadline=None)
def test_flash_equals_dense(s, hkv, g, causal):
    h = hkv * g
    q, k, v = _arr(2, s, h, 16), _arr(2, s, hkv, 16), _arr(2, s, hkv, 16)
    out_f = chunked_sdpa(q, k, v, causal=causal, chunk_q=128, chunk_k=128)
    out_d = _sdpa(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                               rtol=3e-4, atol=3e-4)


def test_flash_gradients_equal_dense():
    q, k, v = _arr(1, 256, 4, 16), _arr(1, 256, 2, 16), _arr(1, 256, 2, 16)

    def loss(f):
        return lambda *a: (f(*a) ** 2).mean()

    gc = jax.grad(loss(lambda q, k, v: chunked_sdpa(q, k, v, True, 64, 64)),
                  argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss(lambda q, k, v: _sdpa(q, k, v, True)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def _mla_cfg():
    return smoke_config("deepseek-v2-236b")


def test_mla_absorbed_prefill_vs_decode():
    """Prefill-style MLA (no cache) must match step-by-step cached decode."""
    cfg = _mla_cfg()
    p = mla_params(jax.random.PRNGKey(0), cfg)
    x = _arr(2, 8, cfg.d_model).astype(cfg.dtype)
    full, _ = mla_attention(p, x, cfg, jnp.arange(8))

    cache = {
        "c_kv": jnp.zeros((2, 8, cfg.kv_lora_rank), cfg.dtype),
        "k_rope": jnp.zeros((2, 8, 1, cfg.rope_head_dim), cfg.dtype),
        "index": jnp.zeros((), jnp.int32),
    }
    outs = []
    for i in range(8):
        o, cache = mla_attention(p, x[:, i:i + 1], cfg, jnp.asarray([i]), cache)
        outs.append(o)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepped, np.float32),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ssd_chunked_equals_recurrent():
    cfg = smoke_config("zamba2-1.2b")
    p = ssm.mamba2_params(jax.random.PRNGKey(1), cfg)
    x = (_arr(2, ssm.CHUNK * 2, cfg.d_model) * 0.1).astype(cfg.dtype)
    y_par, _ = ssm.mamba2_mixer(p, x, cfg, state=None)
    state = ssm.mamba2_state(cfg, 2)
    y_rec, _ = ssm.mamba2_mixer(p, x, cfg, state=state)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_rec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_mlstm_chunkwise_equals_recurrent():
    cfg = smoke_config("xlstm-1.3b")
    p = ssm.mlstm_params(jax.random.PRNGKey(2), cfg)
    x = (_arr(2, ssm.CHUNK * 2, cfg.d_model) * 0.1).astype(cfg.dtype)
    y_par, _ = ssm.mlstm_mixer(p, x, cfg, state=None)
    state = ssm.mlstm_state(cfg, 2)
    y_rec, _ = ssm.mlstm_mixer(p, x, cfg, state=state)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_rec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_slstm_state_continuity():
    """Running sLSTM over [a;b] == running over a then b with carried state."""
    cfg = smoke_config("xlstm-1.3b")
    p = ssm.slstm_params(jax.random.PRNGKey(3), cfg)
    x = (_arr(1, 32, cfg.d_model) * 0.1).astype(cfg.dtype)
    state = ssm.slstm_state(cfg, 1)
    y_full, _ = ssm.slstm_mixer(p, x, cfg, state=state)
    state2 = ssm.slstm_state(cfg, 1)
    y1, state2 = ssm.slstm_mixer(p, x[:, :16], cfg, state=state2)
    y2, _ = ssm.slstm_mixer(p, x[:, 16:], cfg, state=state2)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1), np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=2e-3, atol=2e-3)
