"""Measured calibration of the cost model (sim/calibrate.py) and the trust
chain that lets the tuner use it:

- the least-squares fit recovers known per-resource scale factors from
  synthetic (prediction, measurement) pairs, and degenerate/underdetermined
  data falls back to the identity profile with fit_ok=False — never a
  half-fitted profile;
- profiles round-trip through JSON and persist next to the plan cache keyed
  by hardware fingerprint; calibrated plans carry the profile digest through
  the cache;
- a trusted profile re-ranks the candidate search (and widens the DEFAULT
  space to the hierarchical compositions); an untrusted one changes nothing;
- multidevice (subprocess): calibrated tuning changes at least one of a
  model workload's plans without breaking legality — the routed forward
  still resolves every shape and silent_auto_degrades stays 0.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.autotuner import (CALIBRATED_DATAFLOWS, DEFAULT_DATAFLOWS,
                                  default_dataflows, enumerate_candidates,
                                  tune)
from repro.core.schedule import GEMMShape, Schedule, Tiling, build_program
from repro.deploy import DeploymentPlan, PlanCache, Planner, hw_fingerprint
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.sim.calibrate import (CalibrationProfile, CalibrationSample,
                                 fit_profile, load_profile, save_profile)
from repro.sim.perf import PerfReport, estimate

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
SHAPE = GEMMShape(256, 256, 512)


def synth_report(c, d, n, steps=4, barrier=0.01) -> PerfReport:
    return PerfReport(total_time=max(c, d, n) + barrier, compute_time=c,
                      dma_time=d, noc_time=n, barrier_time=barrier,
                      total_flops=1 << 20, hbm_bytes=1 << 16,
                      noc_bytes=1 << 14, n_supersteps=steps)


def synth_samples(scales=(2.0, 3.0, 0.5), step_s=0.0, n=12, modes=2):
    """Samples whose measurements are exactly the linear model's output."""
    import random
    rng = random.Random(0)
    a, b, c = scales
    out = []
    for i in range(n):
        rep = synth_report(rng.uniform(1, 5), rng.uniform(1, 5),
                           rng.uniform(1, 5), steps=rng.randrange(2, 9))
        sc, sd, sn = rep.resource_shares()
        t = rep.total_time
        measured = (a * t * sc + b * t * sd + c * t * sn
                    + step_s * rep.n_supersteps)
        out.append(CalibrationSample(
            shape=(64 * (i % 3 + 1), 64, 64), dataflow="summa",
            mode=f"mode{i % modes}", report=rep, measured_s=measured))
    return out


def trusted_profile(hw=MINI, **kw) -> CalibrationProfile:
    base = dict(hw_name=hw.name, hw_digest=hw_fingerprint(hw),
                n_samples=12, r2=0.99, fit_ok=True)
    base.update(kw)
    return CalibrationProfile(**base)


# ---------------------------------------------------------------------------
# fit: recovery and degenerate fallback
# ---------------------------------------------------------------------------

def test_fit_recovers_known_scale_factors():
    profile = fit_profile(synth_samples(scales=(2.0, 3.0, 0.5)), MINI)
    assert profile.fit_ok
    assert profile.compute_scale == pytest.approx(2.0, rel=1e-6)
    assert profile.dma_scale == pytest.approx(3.0, rel=1e-6)
    assert profile.noc_scale == pytest.approx(0.5, rel=1e-6)
    assert profile.r2 == pytest.approx(1.0, abs=1e-9)
    assert profile.hw_digest == hw_fingerprint(MINI)


def test_fit_recovers_step_overhead():
    profile = fit_profile(
        synth_samples(scales=(1.0, 1.0, 1.0), step_s=0.25), MINI)
    assert profile.fit_ok
    assert profile.step_overhead_s == pytest.approx(0.25, rel=1e-6)


def test_fit_too_few_samples_is_identity_untrusted():
    profile = fit_profile(synth_samples()[:2], MINI)
    assert not profile.fit_ok
    assert (profile.compute_scale, profile.dma_scale,
            profile.noc_scale) == (1.0, 1.0, 1.0)
    assert profile.step_overhead_s == 0.0


def test_fit_nonpositive_measurements_are_dropped():
    bad = [dataclasses.replace(s, measured_s=0.0) for s in synth_samples()]
    profile = fit_profile(bad, MINI)
    assert not profile.fit_ok
    assert profile.n_samples == 0


def test_fit_identical_measurements_is_degenerate():
    same = [dataclasses.replace(s, measured_s=1.0) for s in synth_samples()]
    profile = fit_profile(same, MINI)
    assert not profile.fit_ok
    assert (profile.compute_scale, profile.dma_scale,
            profile.noc_scale) == (1.0, 1.0, 1.0)


def test_fit_never_returns_negative_scales():
    # measurements anti-correlated with one feature would drive an
    # unconstrained fit negative; the NNLS support search must not
    samples = []
    for i, s in enumerate(synth_samples(scales=(2.0, 0.0, 0.0))):
        samples.append(dataclasses.replace(
            s, measured_s=s.measured_s + (i % 3) * 0.01))
    profile = fit_profile(samples, MINI)
    assert profile.compute_scale >= 0.0
    assert profile.dma_scale >= 0.0
    assert profile.noc_scale >= 0.0
    assert profile.step_overhead_s >= 0.0


def test_untrusted_fit_when_ranking_not_improved():
    """A fit whose calibrated picks measure WORSE than the analytical picks
    must not be trusted, whatever its R^2."""
    samples = synth_samples(scales=(1.0, 1.0, 1.0))
    # flip the measurements of one shape's two modes so the analytical
    # ranking is right and any re-ranking fit would be wrong... the direct
    # gate check: hand fit_profile a perfect linear fit whose rank
    # agreement drops is hard to synthesize, so check the gate directly
    profile = fit_profile(samples, MINI)
    assert profile.rank_agreement_after >= profile.rank_agreement_before
    assert profile.picks_measured_ratio <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# identity semantics + PerfReport.calibrated
# ---------------------------------------------------------------------------

def test_identity_profile_predicts_the_analytical_prior():
    ident = CalibrationProfile.identity(MINI)
    rep = synth_report(2.0, 1.0, 3.0)
    assert ident.predict(rep) == pytest.approx(rep.total_time, rel=1e-12)
    cal = rep.calibrated(ident)
    assert cal.total_time == pytest.approx(rep.total_time, rel=1e-12)
    assert cal.calibration == ident.digest()


def test_calibrated_report_scales_components_and_keeps_invariant():
    prof = trusted_profile(compute_scale=2.0, dma_scale=0.0, noc_scale=0.5,
                           step_overhead_s=0.1)
    rep = synth_report(2.0, 4.0, 1.0, steps=3)
    cal = rep.calibrated(prof)
    assert cal.compute_time == pytest.approx(4.0)
    assert cal.dma_time == pytest.approx(0.0)
    assert cal.noc_time == pytest.approx(0.5)
    # superstep semantics survive any scale combination
    assert cal.total_time >= max(cal.compute_time, cal.dma_time,
                                 cal.noc_time, cal.barrier_time) - 1e-12
    assert cal.total_time >= prof.predict(rep) - 1e-12


# ---------------------------------------------------------------------------
# round-trips and persistence
# ---------------------------------------------------------------------------

def test_profile_json_round_trip():
    profile = fit_profile(synth_samples(), MINI)
    back = CalibrationProfile.from_json(profile.to_json())
    assert back == profile
    assert back.digest() == profile.digest()


def test_profile_rejects_unknown_schema_version():
    profile = fit_profile(synth_samples(), MINI)
    d = profile.to_dict()
    d["schema_version"] = 99
    with pytest.raises(ValueError):
        CalibrationProfile.from_dict(d)


def test_profile_persistence_keyed_by_hw_fingerprint(tmp_path):
    cache_dir = str(tmp_path)
    profile = fit_profile(synth_samples(), MINI)
    save_profile(cache_dir, profile)
    assert load_profile(cache_dir, MINI) == profile
    other = AcceleratorConfig(name="other", grid=(4, 4),
                              tile=TileConfig(l1_bytes=8 * 1024 * 1024),
                              noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
    assert load_profile(cache_dir, other) is None


def test_sample_round_trip():
    s = synth_samples()[0]
    assert CalibrationSample.from_dict(s.to_dict()) == s


def test_calibrated_plan_digest_survives_the_plan_cache(tmp_path):
    """The calibration digest is provenance that must survive persistence:
    plan -> disk -> fresh cache -> same digest (and the report's own
    calibration field round-trips through the plan schema)."""
    profile = trusted_profile(compute_scale=3.0)
    planner = Planner(MINI, cache=PlanCache(str(tmp_path)), elem_bytes=4,
                      max_candidates=8, calibration=profile)
    plan = planner.plan(SHAPE)
    assert plan.calibration_digest == profile.digest()
    reloaded = PlanCache(str(tmp_path))
    back = reloaded.peek(SHAPE, 4, MINI)
    assert back is not None
    assert back.calibration_digest == profile.digest()
    assert back.schedule == plan.schedule
    # a report rescaled by the profile round-trips exactly too
    cal_rep = plan.report.calibrated(profile)
    assert PerfReport.from_dict(cal_rep.to_dict()) == cal_rep


def test_planner_refuses_profile_for_other_hardware():
    wrong = trusted_profile(hw_digest="deadbeefdeadbeef")
    with pytest.raises(ValueError):
        Planner(MINI, calibration=wrong)


# ---------------------------------------------------------------------------
# the tuner trusting (or refusing) a profile
# ---------------------------------------------------------------------------

def test_default_space_widens_only_for_trusted_profiles():
    assert default_dataflows() == list(DEFAULT_DATAFLOWS)
    untrusted = CalibrationProfile.identity(MINI)
    assert default_dataflows(untrusted) == list(DEFAULT_DATAFLOWS)
    trusted = trusted_profile()
    assert default_dataflows(trusted) == (list(DEFAULT_DATAFLOWS)
                                          + list(CALIBRATED_DATAFLOWS))
    # and enumerate_candidates actually yields hierarchical candidates
    dfs = {s.dataflow for s in enumerate_candidates(
        SHAPE, MINI, elem_bytes=4, calibration=trusted)}
    assert set(CALIBRATED_DATAFLOWS) <= dfs
    dfs_prior = {s.dataflow for s in enumerate_candidates(
        SHAPE, MINI, elem_bytes=4)}
    assert not (set(CALIBRATED_DATAFLOWS) & dfs_prior)


def test_untrusted_profile_changes_nothing():
    untrusted = dataclasses.replace(
        trusted_profile(compute_scale=1e4), fit_ok=False)
    base = tune(SHAPE, MINI, elem_bytes=4, max_candidates=16)
    cal = tune(SHAPE, MINI, elem_bytes=4, max_candidates=16,
               calibration=untrusted)
    assert cal.schedule == base.schedule
    assert cal.calibration == ""


def test_calibrated_tuning_changes_a_ranking_legally():
    """A contrived profile (engine mispriced 1e4x) must flip at least one
    tuning decision — and the flipped winner must still be a legal,
    buildable schedule with an analytical report."""
    profile = trusted_profile(compute_scale=1e4)
    shapes = [GEMMShape(256, 256, 512), GEMMShape(128, 512, 1024),
              GEMMShape(64, 256, 2048), GEMMShape(512, 512, 256)]
    flipped = 0
    for shape in shapes:
        base = tune(shape, MINI, elem_bytes=4, max_candidates=24)
        cal = tune(shape, MINI, elem_bytes=4, max_candidates=24,
                   calibration=profile)
        assert cal.calibration == profile.digest()
        # the calibrated winner is legal: it builds and prices
        rep = estimate(build_program(cal.schedule, MINI), MINI)
        assert rep.total_time > 0.0
        # and the calibrated ranking actually preferred it
        assert profile.predict(cal.report) <= profile.predict(base.report) \
            + 1e-12
        flipped += cal.schedule != base.schedule
    assert flipped >= 1, "contrived 1e4x engine mispricing flipped nothing"


def test_warmed_cache_does_not_bypass_calibration(tmp_path):
    """Regression: a cache dir warmed with analytical winners must NOT make
    a later trusted calibration a silent no-op — plans ranked under a
    different regime are re-tuned and replaced, not served as exact hits."""
    shape = GEMMShape(128, 512, 1024)
    cache_dir = str(tmp_path)
    plain = Planner(MINI, cache=PlanCache(cache_dir), elem_bytes=4,
                    max_candidates=24)
    analytical = plain.plan(shape)
    assert analytical.calibration_digest == ""

    profile = trusted_profile(compute_scale=1e4)
    calib = Planner(MINI, cache=PlanCache(cache_dir), elem_bytes=4,
                    max_candidates=24, calibration=profile)
    served = calib.plan(shape)
    assert served.calibration_digest == profile.digest(), (
        "warmed analytical plan was served as a hit by a calibrated planner")
    assert served.schedule != analytical.schedule  # this shape flips (above)
    # and the calibrated winner replaced the analytical one on disk
    reloaded = PlanCache(cache_dir).peek(shape, 4, MINI)
    assert reloaded.calibration_digest == profile.digest()
    # symmetric direction: an analytical planner must not serve the
    # calibrated plan either
    plain2 = Planner(MINI, cache=PlanCache(cache_dir), elem_bytes=4,
                     max_candidates=24)
    assert plain2.plan(shape).calibration_digest == ""


def test_tune_cached_respects_calibration_regime():
    """Regression (tune_cached twin of the Planner fix): a cached
    analytical plan must not be served to a calibrated search, and the
    calibrated winner must persist with its digest."""
    from repro.core.autotuner import tune_cached
    shape = GEMMShape(128, 512, 1024)
    cache = PlanCache()
    first = tune_cached(shape, MINI, cache, elem_bytes=4, max_candidates=24)
    assert first.candidates_tried > 0 and first.calibration == ""
    profile = trusted_profile(compute_scale=1e4)
    calibrated = tune_cached(shape, MINI, cache, elem_bytes=4,
                             max_candidates=24, calibration=profile)
    assert calibrated.candidates_tried > 0, (
        "analytical cache hit served to a calibrated search")
    assert calibrated.calibration == profile.digest()
    assert cache.peek(shape, 4, MINI).calibration_digest == profile.digest()
    # same regime again -> hit, digest preserved
    hit = tune_cached(shape, MINI, cache, elem_bytes=4, max_candidates=24,
                      calibration=profile)
    assert hit.candidates_tried == 0
    assert hit.calibration == profile.digest()


def test_refinement_keeps_calibrated_winner(tmp_path):
    """Regression: background refinement must compare by the planner's
    ranking cost — a calibrated winner with a worse *analytical* estimate
    must survive its own refinement, and the recorded costs are calibrated."""
    profile = trusted_profile(compute_scale=1e4)
    planner = Planner(MINI, cache=PlanCache(str(tmp_path)), elem_bytes=4,
                      max_candidates=24, calibration=profile)
    shape = GEMMShape(128, 512, 1024)
    tuned = planner.plan(shape)
    planner._pending.append(shape)          # force a refinement pass
    [(s, old_c, new_c)] = planner.refine_pending()
    assert s == shape
    assert new_c <= old_c + 1e-12
    after = planner.plan_cached(shape)
    assert after.schedule == tuned.schedule, (
        "refinement un-picked the calibrated winner")
    assert after.calibration_digest == profile.digest()


# ---------------------------------------------------------------------------
# multidevice: calibrated planner routes a model with zero silent degrades
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MULTIDEVICE_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import smoke_config
    from repro.deploy import Planner, hw_fingerprint, model_workload
    from repro.hw.config import (AcceleratorConfig, HBMConfig, NoCConfig,
                                 TileConfig)
    from repro.models import shard_ctx
    from repro.models.model import forward, init_params
    from repro.models.shard_ctx import GemmContext
    from repro.sim.calibrate import CalibrationProfile

    MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                             tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                             noc=NoCConfig(), hbm=HBMConfig(n_channels=8))
    profile = CalibrationProfile(hw_name=MINI.name,
                                 hw_digest=hw_fingerprint(MINI),
                                 compute_scale=1e4, n_samples=12, r2=0.99,
                                 fit_ok=True)
    cfg = smoke_config("gemma-2b")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    base = np.asarray(forward(params, toks, cfg), np.float32)
    workload = model_workload(cfg, 4, 16, kind="prefill")

    plain = Planner(MINI, elem_bytes=4, max_candidates=24)
    calib = Planner(MINI, elem_bytes=4, max_candidates=24,
                    calibration=profile)
    plain.batch_tune(workload)
    calib.batch_tune(workload)
    changed = [s for s in workload
               if plain.plan_cached(s).schedule != calib.plan_cached(s).schedule]
    assert changed, "calibration flipped no workload plan"
    for s in workload:
        assert calib.plan_cached(s).calibration_digest == profile.digest()

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ctx = GemmContext(mesh=mesh, planner=calib)
    shard_ctx.set_gemm_context(ctx)
    routed = np.asarray(
        jax.jit(lambda p, t: forward(p, t, cfg))(params, toks), np.float32)
    shard_ctx.set_gemm_context(None)

    s = ctx.stats
    assert s.routed > 0, "nothing routed"
    assert s.resolve_rate == 1.0, s.describe()
    assert s.silent_degrades == 0, s.describe()
    np.testing.assert_allclose(routed, base, rtol=5e-2, atol=5e-2)
    print("changed plans:", len(changed), "stats:", s.describe())
    print("ALL_OK")
""")


@pytest.mark.slow
def test_calibrated_routing_multidevice():
    """Calibrated tuning changes rankings AND the routed forward still
    resolves 100% with zero silent degrades on a real multi-device mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", MULTIDEVICE_BODY], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout
