"""Multi-device equivalence of the distributed dit_gemm dataflow modes.

These need >1 JAX device, so they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (per the dry-run rules the
main test process must keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.gemm import (allgather_gemm, auto_gemm, cannon_gemm,
                                 dit_gemm, splitk_gemm, summa_gemm)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    M, K, N = 64, 128, 96
    a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.float32)
    ref = np.asarray(a @ b)

    for mode in ("auto", "summa", "cannon", "allgather"):
        out = np.asarray(jax.jit(
            lambda x, y, m=mode: dit_gemm(x, y, mesh, mode=m))(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        print("OK", mode)
    # split-K over the model axis, both reduction-owner policies
    for scatter in (True, False):
        out = np.asarray(jax.jit(
            lambda x, y, s=scatter: splitk_gemm(x, y, mesh, "model", s))(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        print("OK splitk scatter=", scatter)
    # 1x4 logical view (cluster remap analogue): splitk over the long axis
    mesh14 = jax.make_mesh((1, 4), ("data", "model"))
    out = np.asarray(jax.jit(
        lambda x, y: splitk_gemm(x, y, mesh14, "model", True))(a, b))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    print("OK splitk remap 1x4")
    print("ALL_OK")
""")


PLAN_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.gemm import dit_gemm
    from repro.core.schedule import GEMMShape, Schedule, Tiling

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    M, K, N = 64, 128, 64
    a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.float32)
    ref = np.asarray(a @ b)

    # a tuned schedule's dataflow decides the collective pattern
    for df, owner in (("summa", "first"), ("systolic", "first"),
                      ("splitk_summa", "round_robin"),
                      ("splitk_summa", "first"), ("baseline", "first")):
        gk = 4 if df == "splitk_summa" else 1
        sched = Schedule(GEMMShape(M, N, K), Tiling(2, 2, gk, tk=32), df,
                         reduce_owner=owner)
        out = np.asarray(jax.jit(
            lambda x, y, s=sched: dit_gemm(x, y, mesh, plan=s))(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        print("OK plan", df, owner)
    print("ALL_OK")
""")


KERNEL_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.gemm import dit_gemm
    from repro.core.lower import lower_schedule
    from repro.core.schedule import GEMMShape, InnerKernel, Schedule, Tiling

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    M, K, N = 64, 128, 64
    a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.float32)
    ref = np.asarray(a @ b)

    def routed(sched):
        ep = lower_schedule(sched, mesh, "data", "model", shape=(M, N, K))
        assert not ep.degraded, ep.describe()
        return ep, np.asarray(jax.jit(
            lambda x, y, e=ep: dit_gemm(x, y, mesh, exec_plan=e))(a, b))

    ik = InnerKernel(32, 32, 32, dtype="float32")
    for df, overlap in (("summa", False), ("systolic", False),
                        ("systolic", True), ("splitk_summa", False)):
        gk = 2 if df == "splitk_summa" else 1
        base = Schedule(GEMMShape(M, N, K), Tiling(2, 2 // gk, gk, tk=32),
                        df, reduce_owner="round_robin" if gk > 1 else "first")
        two = dataclasses.replace(base, inner_kernel=ik, overlap=overlap)
        ep_off, out_off = routed(base)
        ep_on, out_on = routed(two)
        assert ep_on.inner_kernel == ik, ep_on.describe()
        assert ep_on.overlap == overlap, ep_on.describe()
        # on CPU the kernel path IS the jnp.dot oracle and overlap is a
        # pure reordering: engaging the inner level must be BITWISE free
        np.testing.assert_array_equal(out_on, out_off)
        np.testing.assert_allclose(out_on, ref, rtol=1e-4, atol=1e-4)
        print("OK kernel", df, "overlap=", overlap)

    # grad parity through the routed, kernel-aware ring with overlap on
    ep, _ = routed(Schedule(GEMMShape(M, N, K), Tiling(2, 2, 1, tk=32),
                            "systolic", inner_kernel=ik, overlap=True))
    def loss_routed(x, y):
        return (dit_gemm(x, y, mesh, exec_plan=ep) ** 2).sum()
    def loss_ref(x, y):
        return (jnp.dot(x, y, preferred_element_type=jnp.float32) ** 2).sum()
    ga_r, gb_r = jax.grad(loss_routed, argnums=(0, 1))(a, b)
    ga_o, gb_o = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_r), np.asarray(ga_o),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_r), np.asarray(gb_o),
                               rtol=1e-4, atol=1e-4)
    print("OK grad")
    print("ALL_OK")
""")


def _run_subprocess(body):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout


@pytest.mark.slow
def test_gemm_modes_multidevice():
    _run_subprocess(BODY)


@pytest.mark.slow
def test_plan_driven_dispatch_multidevice():
    """dit_gemm(plan=...) resolves the tuned dataflow to the right mode."""
    _run_subprocess(PLAN_BODY)


@pytest.mark.slow
def test_inner_kernel_and_overlap_multidevice():
    """Engaging the schedule's inner level (kernel + ring overlap) is
    bitwise free on the CPU mesh and differentiable through the routed
    path."""
    _run_subprocess(KERNEL_BODY)
