"""Multi-device equivalence of the distributed dit_gemm dataflow modes.

These need >1 JAX device, so they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (per the dry-run rules the
main test process must keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.gemm import (allgather_gemm, auto_gemm, cannon_gemm,
                                 dit_gemm, splitk_gemm, summa_gemm)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    M, K, N = 64, 128, 96
    a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.float32)
    ref = np.asarray(a @ b)

    for mode in ("auto", "summa", "cannon", "allgather"):
        out = np.asarray(jax.jit(
            lambda x, y, m=mode: dit_gemm(x, y, mesh, mode=m))(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        print("OK", mode)
    # split-K over the model axis, both reduction-owner policies
    for scatter in (True, False):
        out = np.asarray(jax.jit(
            lambda x, y, s=scatter: splitk_gemm(x, y, mesh, "model", s))(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        print("OK splitk scatter=", scatter)
    # 1x4 logical view (cluster remap analogue): splitk over the long axis
    mesh14 = jax.make_mesh((1, 4), ("data", "model"))
    out = np.asarray(jax.jit(
        lambda x, y: splitk_gemm(x, y, mesh14, "model", True))(a, b))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    print("OK splitk remap 1x4")
    print("ALL_OK")
""")


PLAN_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.gemm import dit_gemm
    from repro.core.schedule import GEMMShape, Schedule, Tiling

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    M, K, N = 64, 128, 64
    a = jnp.asarray(rng.standard_normal((M, K)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype=jnp.float32)
    ref = np.asarray(a @ b)

    # a tuned schedule's dataflow decides the collective pattern
    for df, owner in (("summa", "first"), ("systolic", "first"),
                      ("splitk_summa", "round_robin"),
                      ("splitk_summa", "first"), ("baseline", "first")):
        gk = 4 if df == "splitk_summa" else 1
        sched = Schedule(GEMMShape(M, N, K), Tiling(2, 2, gk, tk=32), df,
                         reduce_owner=owner)
        out = np.asarray(jax.jit(
            lambda x, y, s=sched: dit_gemm(x, y, mesh, plan=s))(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        print("OK plan", df, owner)
    print("ALL_OK")
""")


def _run_subprocess(body):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", body], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout


@pytest.mark.slow
def test_gemm_modes_multidevice():
    _run_subprocess(BODY)


@pytest.mark.slow
def test_plan_driven_dispatch_multidevice():
    """dit_gemm(plan=...) resolves the tuned dataflow to the right mode."""
    _run_subprocess(PLAN_BODY)
