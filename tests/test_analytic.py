"""The closed-form candidate generator (core/analytic.py) and the planner's
online-tuning path built on it.

Covers the PR-7 contracts:
- rank agreement: the shortlist's best candidate matches the exhaustive
  `tune` optimum >= 90% of shapes and costs <= 1.05x the optimum everywhere,
  under BOTH the analytical prior and a fitted (trusted) CalibrationProfile
  — the generator must track whichever objective the planner ranks by
  (benchmarks/analytic_bench.py runs the same gate over the dense grids and
  writes BENCH_analytic.json);
- legality: every emitted Schedule builds a program and lowers onto the
  matching mesh with zero silent degrades;
- hypothesis properties: deterministic output, >= 1 legal candidate for
  divisible shapes, shortlist size respects k, candidates are deduped;
- the serving loop: `plan_cached` misses online-tune into `analytic`-source
  plans, `plan` never serves them, background refinement upgrades them to
  `tuned`, and the bucketed-transfer path never seeds from one (the
  tuned-only-sources rule extended to online plans).
"""
import dataclasses

import pytest

from repro.core.analytic import (DEFAULT_SHORTLIST_K, TOP1_TIE_RTOL,
                                 agreement_stats, analytic_shortlist,
                                 analytic_tune)
from repro.core.lower import lower_schedule
from repro.core.schedule import GEMMShape, build_program
from repro.deploy.bucketing import BucketingPolicy
from repro.deploy.plan import (SOURCE_ANALYTIC, SOURCE_BUCKETED,
                               SOURCE_TUNED, hw_fingerprint)
from repro.deploy.planner import Planner
from repro.hw.config import AcceleratorConfig, HBMConfig, NoCConfig, TileConfig
from repro.sim.calibrate import CalibrationProfile

MINI = AcceleratorConfig(name="mini", grid=(4, 4),
                         tile=TileConfig(l1_bytes=4 * 1024 * 1024),
                         noc=NoCConfig(), hbm=HBMConfig(n_channels=8))

# a trusted profile with deliberately skewed terms (compute up, DMA down,
# NoC up) — enough to flip winners vs the analytical prior, so calibrated
# agreement is a distinct check, not a repeat of the identity one
PROFILE = CalibrationProfile(hw_name=MINI.name, hw_digest=hw_fingerprint(MINI),
                             compute_scale=1.35, dma_scale=0.8,
                             noc_scale=1.25, step_overhead_s=1e-6,
                             n_samples=12, r2=0.97, fit_ok=True)

# the tier-1 agreement grid: small enough that the exhaustive baselines stay
# test-sized, spanning tall/wide/square aspects and shallow/deep K (the
# dense 36-shape grid is the benchmark's job)
GRID = [GEMMShape(m, n, k) for m in (256, 1024, 4096)
        for n in (256, 1024) for k in (256, 8192)]


# ---------------------------------------------------------------------------
# rank agreement vs exhaustive search
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("calibration", [None, PROFILE],
                         ids=["identity", "calibrated"])
def test_rank_agreement_vs_exhaustive(calibration):
    stats = agreement_stats(GRID, MINI, calibration=calibration,
                            max_exhaustive=96)
    misses = [s["shape"] for s in stats["per_shape"] if not s["top1"]]
    assert stats["top1_rate"] >= 0.9, (
        f"top1={stats['top1_rate']:.3f}, misses: {misses}")
    assert stats["max_cost_ratio"] <= 1.05, stats["max_cost_ratio"]
    # generation latency is asserted tightly (<1ms) by the benchmark on an
    # unloaded run; here a loose sanity bound keeps the order of magnitude
    assert stats["max_gen_us"] < 20_000, stats["max_gen_us"]


# ---------------------------------------------------------------------------
# legality of every emitted candidate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("calibration", [None, PROFILE],
                         ids=["identity", "calibrated"])
def test_shortlist_schedules_legal_and_lower_cleanly(calibration):
    """Every shortlist Schedule must build a program (the full legality
    check: divisibility + L1 capacity) and lower onto the matching mesh
    without a silent degrade (auto mode with no recorded reason)."""
    mesh = type("M", (), {"shape": {"data": MINI.grid[0],
                                    "model": MINI.grid[1]}})()
    for shape in GRID:
        for sched in analytic_shortlist(shape, MINI,
                                        calibration=calibration):
            build_program(sched, MINI)          # raises if illegal
            ep = lower_schedule(sched, mesh, shape=shape)
            assert not (ep.mode == "auto" and not ep.fallbacks), \
                f"silent degrade: {sched.describe()}"


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

# function-scoped importorskip (not the module-level test_perf_properties.py
# form: THIS module's non-property tests must still run without hypothesis)
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False


def _key(s):
    return (s.tiling, s.dataflow, s.acc_bytes, s.store_stages,
            s.double_buffer, s.inner)


if _HAS_HYPOTHESIS:
    _pow2 = st.sampled_from([64, 128, 256, 512, 1024, 2048, 4096])
    shapes = st.builds(GEMMShape, m=_pow2, n=_pow2, k=_pow2)

    @given(shape=shapes, k=st.sampled_from([1, 4, 16, DEFAULT_SHORTLIST_K]))
    @settings(max_examples=60, deadline=None)
    def test_shortlist_properties(shape, k):
        """Deterministic, sized <= k, deduped, and non-empty for divisible
        (pow-2) shapes — every candidate targeting the requested shape."""
        first = analytic_shortlist(shape, MINI, k=k)
        second = analytic_shortlist(shape, MINI, k=k)
        assert [_key(s) for s in first] == [_key(s) for s in second]
        assert 1 <= len(first) <= k
        assert len({_key(s) for s in first}) == len(first)
        for sched in first:
            assert sched.shape == shape
            build_program(sched, MINI)

    @given(shape=shapes)
    @settings(max_examples=20, deadline=None)
    def test_analytic_tune_prices_a_winner(shape):
        res = analytic_tune(shape, MINI)
        assert res.schedule.shape == shape
        assert res.candidates_tried >= 1
        assert res.report.total_time > 0
else:
    def test_shortlist_properties():
        pytest.importorskip("hypothesis")

    def test_analytic_tune_prices_a_winner():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# the serving loop: online tune -> refine -> provenance
# ---------------------------------------------------------------------------

def test_plan_cached_online_tunes_and_refines():
    planner = Planner(MINI, elem_bytes=1, max_candidates=48)
    shape = GEMMShape(1024, 2048, 1024)
    plan = planner.plan_cached(shape)
    assert plan is not None and plan.source == SOURCE_ANALYTIC
    # served again: the analytic entry is an exact hit on the serving path
    assert planner.plan_cached(shape).source == SOURCE_ANALYTIC
    # but never satisfies `plan` — the full search replaces it
    assert planner.pending_refinements == (shape,)
    planner.refine_pending()
    refined = planner.cache.peek(shape, 1, MINI, planner.variant)
    assert refined.source == SOURCE_TUNED
    assert planner.pending_refinements == ()
    # and the refined winner is no worse than the shortlist's
    assert refined.report.total_time <= plan.report.total_time * (1 + 1e-9)


def test_plan_never_serves_analytic_entry():
    planner = Planner(MINI, elem_bytes=1, max_candidates=16)
    shape = GEMMShape(512, 512, 512)
    online = planner.plan_cached(shape)
    assert online.source == SOURCE_ANALYTIC
    full = planner.plan(shape)
    assert full.source == SOURCE_TUNED


def test_online_tune_flag_disables_the_path():
    planner = Planner(MINI, elem_bytes=1, online_tune=False)
    assert planner.plan_cached(GEMMShape(512, 512, 512)) is None


def test_bucketed_transfer_never_seeds_from_analytic_plan():
    """Regression (PR-7 satellite): an analytic (unrefined) cache entry must
    not become a bucketed-transfer source — that would chain a second
    unvalidated approximation onto the first. The same neighbour DOES seed
    a transfer once refinement upgrades it to `tuned`."""
    policy = BucketingPolicy(max_transfers=3)
    planner = Planner(MINI, elem_bytes=1, max_candidates=48, policy=policy)
    src_shape = GEMMShape(1024, 1024, 1024)
    online = planner.plan_cached(src_shape)
    assert online.source == SOURCE_ANALYTIC
    # a nearby shape: the analytic neighbour is its only transfer candidate,
    # and the guard must skip it — the miss online-tunes instead
    near = GEMMShape(2048, 1024, 1024)
    served = planner.plan_cached(near)
    assert served is not None and served.source == SOURCE_ANALYTIC
    # upgrade the source to tuned (what refinement does) and re-ask with a
    # different nearby shape: now the transfer is allowed
    planner.cache.put(dataclasses.replace(online, source=SOURCE_TUNED))
    other = GEMMShape(512, 1024, 1024)
    transferred = planner.plan_cached(other)
    assert transferred is not None and transferred.source == SOURCE_BUCKETED
