"""Sharding rule table + fused-loss numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.models.model import init_params, lm_head_weight
from repro.train.steps import chunked_xent


def _mesh_stub():
    # spec fitting only needs axis sizes; use the real device for a 1x1 mesh
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_spec_fitting_drops_indivisible_axes():
    from repro.parallel.spec_rules import _fit
    mesh = _mesh_stub()

    class M:
        shape = {"data": 16, "model": 16}
    spec = _fit(P("data", "model"), (64, 160), M)
    # 64 % 16 == 0 keeps 'data'; 160 % 16 == 0 keeps 'model'
    assert spec == P("data", "model")
    spec = _fit(P("data", "model"), (60, 160), M)
    assert spec == P(None, "model")
    spec = _fit(P(("pod", "data"), None), (8, 4), type("M2", (), {
        "shape": {"pod": 2, "data": 16}}))
    assert spec == P(None, None)     # 8 % 32 != 0


def test_cache_spec_prefers_heads_then_seq():
    from repro.parallel.spec_rules import cache_spec
    cfg = smoke_config("qwen3-14b")

    class M:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    class KeyEntry:
        def __init__(self, k):
            self.key = k

    # qwen3: kv=8 < 16 -> sequence sharding fallback on dim 2
    leaf = jax.ShapeDtypeStruct((40, 128, 32768, 8, 128), jnp.bfloat16)
    spec = cache_spec((KeyEntry("layers"), KeyEntry("k")), leaf, M, cfg, 128)
    assert spec[3] is None and spec[2] == "model"
    # kv divisible -> head sharding
    leaf2 = jax.ShapeDtypeStruct((40, 128, 32768, 32, 128), jnp.bfloat16)
    spec2 = cache_spec((KeyEntry("layers"), KeyEntry("k")), leaf2, M, cfg, 128)
    assert spec2[3] == "model"


def test_chunked_xent_matches_direct():
    cfg = smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    hidden = jnp.asarray(rng.standard_normal((2, 24, cfg.d_model)) * 0.1,
                         cfg.dtype)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (2, 24)), jnp.int32)
    w = lm_head_weight(params, cfg)
    fused = chunked_xent(hidden, w, targets, cfg.vocab, chunk=8)
    logits = (hidden @ w).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, -1)
    direct = -jnp.take_along_axis(logp, targets[..., None], -1).mean()
    np.testing.assert_allclose(float(fused), float(direct), rtol=1e-5)


def test_chunked_xent_gradients_flow():
    cfg = smoke_config("olmo-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    hidden = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.1,
                         jnp.float32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    w = lm_head_weight(params, cfg).astype(jnp.float32)
    g = jax.grad(lambda h: chunked_xent(h, w, targets, cfg.vocab, chunk=8))(hidden)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).max()) > 0


def test_hlo_collective_parser():
    from repro.launch.hlo_stats import collective_stats
    hlo = """
HloModule m
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64] parameter(0)
  %ag = f32[128,64] all-gather(%p), replica_groups={{0,1}}, dimensions={0}
  %ar = f32[128,64] all-reduce(%ag), to_apply=%add
  ROOT %out = f32[64,64] slice(%ar), slice={[0:64], [0:64]}
}
"""
    stats = collective_stats(hlo)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.bytes_by_kind["all-gather"] == 128 * 64 * 4
