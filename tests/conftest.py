"""Test-process invariants. NOTE: per the dry-run rules, XLA_FLAGS device
forcing must never leak into the test process — smoke tests see 1 device;
multi-device tests run in subprocesses (tests/test_gemm_modes.py)."""
import os


def test_env_guard():
    pass


def pytest_configure(config):
    flags = os.environ.get("XLA_FLAGS", "")
    assert "host_platform_device_count" not in flags, (
        "XLA_FLAGS device forcing leaked into the test environment; "
        "dry-runs must set it in their own subprocess only")
