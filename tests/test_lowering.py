"""Schedule->mesh lowering (repro.core.lower) — the ExecPlan contract.

Two halves:

1. **Resolution + fallback reasons** (no devices): `lower_schedule` only
   needs `mesh.shape`, so every branch of the lowering — each DATAFLOWS
   name, each mesh-view construction, and each machine-readable fallback
   reason — is pinned with bare namespace meshes. The two hierarchical
   compositions resolve to DISTINCT modes (Fig. 6d -> `hierarchical`,
   Fig. 6c -> `outer_systolic`), with the Fig. 6c ring legality
   (square outer grid of >= 2) pinned branch by branch.
2. **Execution parity** (slow, subprocess with fake devices): every resolved
   mode — including the nested 3-D `splitk_summa`, the `hierarchical`
   outer-SUMMA-over-inner-Cannon mode, and the `outer_systolic` outer
   Cannon ring of inner SUMMA groups — matches the `auto` baseline
   numerically on 2x2 / 2x4 / 4x4 meshes, the tuned gk>1 grid executes
   true 3-D split-K on an 8-device mesh (the ROADMAP acceptance), and the
   new modes are reverse-differentiable. A separate subprocess proves a
   Fig. 6c-tuned schedule reaches `outer_systolic` through the `pmm`
   routed-dispatch path.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import pytest

from repro.core import lower
from repro.core.lower import (ExecPlan, Fallback, MeshView, lower_schedule,
                              lowering_summary)
from repro.core.schedule import (DATAFLOWS, INNER_VMEM_BUDGET, GEMMShape,
                                 InnerKernel, Schedule, Tiling)


def mesh2(dm, dn):
    return SimpleNamespace(shape={"data": dm, "model": dn},
                           axis_names=("data", "model"))


def sched(df, m=64, n=64, k=128, gm=2, gn=2, gk=1, owner="first",
          inner=(2, 2)):
    return Schedule(GEMMShape(m, n, k), Tiling(gm, gn, gk, tk=32), df,
                    reduce_owner=owner, inner=inner)


# ---------------------------------------------------------------------------
# every dataflow name has an explicit lowering
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("df", DATAFLOWS)
@pytest.mark.parametrize("mesh", [mesh2(2, 2), mesh2(2, 4), mesh2(4, 4)],
                         ids=["2x2", "2x4", "4x4"])
def test_every_dataflow_lowers(df, mesh):
    """Regression for the silent default branch: every name in DATAFLOWS —
    including both hierarchical compositions — resolves without error and
    lands on a known mode."""
    ep = lower_schedule(sched(df, gk=2 if df == "splitk_summa" else 1), mesh)
    assert isinstance(ep, ExecPlan)
    assert ep.mode in lower.EXEC_MODES
    assert ep.requested == df
    # the Fig. 6d composition gets the hierarchical mode, never a summa
    # collapse; Fig. 6c gets outer_systolic where the outer ring fits
    # (square outer grid >= 2, i.e. the 4x4 mesh) and hierarchical elsewhere
    if df == "summa_over_systolic":
        assert ep.mode == "hierarchical"
        assert ep.axes["inner_row"] == "data_in"
    if df == "systolic_over_summa":
        dm, dn = mesh.shape["data"], mesh.shape["model"]
        want = "outer_systolic" if (dm == dn and dm // 2 >= 2) \
            else "hierarchical"
        assert ep.mode == want
        assert ep.axes["inner_row"] == "data_in"
    if df == "splitk_summa":
        assert ep.mode == "splitk_summa"


def test_unknown_dataflow_reason():
    ep = lower_schedule(sched("warp_drive"), mesh2(2, 2))
    assert ep.mode == "summa"
    assert ep.reasons() == (lower.UNKNOWN_DATAFLOW,)


# ---------------------------------------------------------------------------
# mesh-view construction: the tuned grid survives to execution
# ---------------------------------------------------------------------------

def test_splitk_view_factors_col_axis():
    ep = lower_schedule(sched("splitk_summa", gk=2, owner="round_robin"),
                        mesh2(2, 4))
    assert ep.mode == "splitk_summa" and not ep.fallbacks
    assert ep.kwargs["scatter"] is True
    sizes = ep.view.axis_sizes(mesh2(2, 4))
    assert sizes == {"data": 2, "model": 2, "splitk": 2}


def test_splitk_view_factors_row_axis():
    # gk does not divide the 1-wide column axis; it factors out of the rows
    ep = lower_schedule(sched("splitk_summa", gk=2), mesh2(4, 1))
    assert ep.mode == "splitk_summa" and not ep.fallbacks
    assert ep.view.axis_sizes(mesh2(4, 1)) == {"data": 2, "splitk": 2,
                                               "model": 1}


def test_splitk_grid_mismatch_collapses_to_1d():
    ep = lower_schedule(sched("splitk_summa", gk=3), mesh2(2, 4))
    assert ep.mode == "splitk"
    assert ep.reasons() == (lower.GRID_MISMATCH,)
    assert ep.axes["k"] == "model" and ep.view is None
    assert not ep.degraded          # 1-D split-K still honors the dataflow


def test_splitk_gk_one_is_summa():
    ep = lower_schedule(sched("splitk_summa", gk=1), mesh2(2, 2))
    assert ep.mode == "summa"
    assert ep.reasons() == (lower.GK_IS_ONE,)


def test_hierarchical_view():
    ep = lower_schedule(sched("summa_over_systolic", inner=(2, 2)),
                        mesh2(2, 4))
    assert ep.mode == "hierarchical" and not ep.fallbacks
    assert ep.view.axis_sizes(mesh2(2, 4)) == {
        "data": 1, "data_in": 2, "model": 2, "model_in": 2}
    assert ep.kwargs["inner"] == (2, 2)


def test_outer_systolic_view():
    """Fig. 6c resolves to its own mode on a square outer grid — same
    4-axis view as hierarchical, distinct collective program."""
    ep = lower_schedule(sched("systolic_over_summa", inner=(2, 2)),
                        mesh2(4, 4))
    assert ep.mode == "outer_systolic" and not ep.fallbacks
    assert ep.view.axis_sizes(mesh2(4, 4)) == {
        "data": 2, "data_in": 2, "model": 2, "model_in": 2}
    assert ep.kwargs["inner"] == (2, 2)
    assert ep.axes["inner_row"] == "data_in"
    assert ep.axes["inner_col"] == "model_in"


def test_outer_systolic_production_mesh():
    """The 16x16 production grid: an 8x8 outer ring of 2x2 SUMMA groups."""
    ep = lower_schedule(sched("systolic_over_summa", m=256, n=256, k=2048),
                        mesh2(16, 16))
    assert ep.mode == "outer_systolic" and not ep.fallbacks
    assert ep.view.axis_sizes(mesh2(16, 16)) == {
        "data": 8, "data_in": 2, "model": 8, "model_in": 2}


def test_view_materialize_preserves_extra_axes():
    """A multi-pod mesh's pod axis passes through the view untouched."""
    view = MeshView(splits=(("model", (("model", 2), ("splitk", 2))),))
    pod_mesh = SimpleNamespace(shape={"pod": 2, "data": 2, "model": 4},
                               axis_names=("pod", "data", "model"))
    assert view.axis_sizes(pod_mesh) == {"pod": 2, "data": 2, "model": 2,
                                         "splitk": 2}


# ---------------------------------------------------------------------------
# fallback reasons, branch by branch
# ---------------------------------------------------------------------------

def test_non_square_systolic():
    ep = lower_schedule(sched("systolic"), mesh2(2, 4))
    assert ep.mode == "summa"
    assert ep.reasons() == (lower.NON_SQUARE_SYSTOLIC,)
    assert not ep.degraded


def test_non_square_inner():
    ep = lower_schedule(sched("summa_over_systolic", inner=(1, 2)),
                        mesh2(2, 4))
    assert ep.mode == "summa"
    assert ep.reasons() == (lower.NON_SQUARE_INNER,)


def test_inner_grid_mismatch():
    ep = lower_schedule(sched("systolic_over_summa", inner=(3, 3)),
                        mesh2(4, 4))
    assert ep.mode == "summa"
    assert ep.reasons() == (lower.INNER_GRID_MISMATCH,)


def test_non_square_outer_falls_to_hierarchical():
    """Fig. 6c's ring needs a square outer grid; a rectangular one still
    executes the hierarchical (Fig. 6d-shaped) composition, not summa."""
    ep = lower_schedule(sched("systolic_over_summa", inner=(2, 2)),
                        mesh2(4, 8))
    assert ep.mode == "hierarchical"
    assert ep.reasons() == (lower.NON_SQUARE_OUTER,)
    assert ep.fallbacks[0].from_mode == "outer_systolic"
    assert not ep.degraded
    # the 4-axis view survives the fallback — hierarchical runs on it
    assert ep.view.axis_sizes(mesh2(4, 8)) == {
        "data": 2, "data_in": 2, "model": 4, "model_in": 2}


def test_outer_ring_too_small_falls_to_hierarchical():
    """A 1x1 outer grid has no ring to rotate chunks around."""
    ep = lower_schedule(sched("systolic_over_summa", inner=(2, 2)),
                        mesh2(2, 2))
    assert ep.mode == "hierarchical"
    assert ep.reasons() == (lower.OUTER_RING_TOO_SMALL,)
    assert not ep.degraded


def test_outer_systolic_k_indivisible_degrades_to_auto():
    # the ring fits (2x2 outer of 2x2 inner), but K=132 % (Om*ih^2)=8 != 0
    ep = lower_schedule(sched("systolic_over_summa", k=132), mesh2(4, 4))
    assert ep.mode == "auto" and ep.degraded
    assert ep.reasons() == (lower.K_NOT_DIVISIBLE,)
    assert ep.fallbacks[0].from_mode == "outer_systolic"


def test_outer_systolic_non_square_inner_reports_wanted_mode():
    """A non-square inner group on the Fig. 6c composition records the
    fallback as coming FROM outer_systolic (what the schedule asked for)."""
    ep = lower_schedule(sched("systolic_over_summa", inner=(1, 2)),
                        mesh2(4, 4))
    assert ep.mode == "summa"
    assert ep.reasons() == (lower.NON_SQUARE_INNER,)
    assert ep.fallbacks[0].from_mode == "outer_systolic"


@pytest.mark.parametrize("df,shape,reason", [
    ("summa", (63, 64, 128), lower.M_NOT_DIVISIBLE),
    ("summa", (64, 63, 128), lower.N_NOT_DIVISIBLE),
    ("summa", (64, 64, 130), lower.K_NOT_DIVISIBLE),
    ("systolic", (64, 64, 127), lower.K_NOT_DIVISIBLE),
    ("baseline", (63, 64, 128), lower.M_NOT_DIVISIBLE),
    ("baseline", (64, 64, 127), lower.K_NOT_DIVISIBLE),
])
def test_indivisible_degrades_to_auto(df, shape, reason):
    m, n, k = shape
    ep = lower_schedule(sched(df, m=m, n=n, k=k), mesh2(2, 2))
    assert ep.mode == "auto" and ep.degraded
    assert ep.fallbacks[-1] == Fallback(reason, ep.fallbacks[-1].from_mode,
                                        "auto")


def test_splitk_3d_k_indivisible_degrades_to_auto():
    # gk=2 fits the mesh, but K=130 % (gk*rm*rn)=8 != 0
    ep = lower_schedule(sched("splitk_summa", gk=2, k=130), mesh2(2, 4))
    assert ep.mode == "auto"
    assert ep.reasons() == (lower.K_NOT_DIVISIBLE,)


def test_splitk_scatter_demotes_not_degrades():
    # round_robin wants psum_scatter, but M=2 < rm*gk=4: the reduction
    # demotes to the replicated-C psum ('first' analogue), mode unchanged
    ep = lower_schedule(sched("splitk_summa", gk=2, m=2, owner="round_robin"),
                        mesh2(2, 4))
    assert ep.mode == "splitk_summa"
    assert ep.kwargs["scatter"] is False
    assert lower.SCATTER_M_INDIVISIBLE in ep.reasons()
    assert not ep.degraded


def test_splitk_1d_scatter_demotion():
    # grid mismatch -> 1-D splitk over the 4-wide model axis; M=2 % 4 != 0
    # demotes scatter there too (the old inline dit_gemm check, now in one
    # place so dispatch and validation cannot drift)
    ep = lower_schedule(sched("splitk_summa", gk=3, m=2, owner="round_robin"),
                        mesh2(2, 4))
    assert ep.mode == "splitk" and ep.kwargs["scatter"] is False
    assert ep.reasons() == (lower.GRID_MISMATCH, lower.SCATTER_M_INDIVISIBLE)


def test_fallback_chain_hierarchical_to_auto():
    # inner group fits, but K % (Om*On*ih) fails -> hierarchical -> auto
    ep = lower_schedule(sched("summa_over_systolic", inner=(2, 2), k=126),
                        mesh2(2, 4))
    assert ep.mode == "auto"
    assert ep.reasons() == (lower.K_NOT_DIVISIBLE,)
    assert ep.fallbacks[0].from_mode == "hierarchical"


def test_overrides_validated_before_dispatch():
    """Caller kwargs merge BEFORE legality: forcing scatter on an
    M-indivisible problem is demoted, not crashed."""
    ep = lower_schedule(sched("splitk_summa", gk=2, m=2, owner="first"),
                        mesh2(2, 4), overrides={"scatter": True})
    assert ep.kwargs["scatter"] is False
    assert lower.SCATTER_M_INDIVISIBLE in ep.reasons()


def test_shape_override_beats_schedule_shape():
    """Bucketed serving dispatches neighbour shapes: legality must check the
    actual operands, not the tuned shape."""
    tuned = sched("summa", m=64, n=64, k=128)
    ok = lower_schedule(tuned, mesh2(2, 2))
    assert ok.mode == "summa"
    served = lower_schedule(tuned, mesh2(2, 2), shape=(64, 64, 130))
    assert served.mode == "auto"
    assert lower.K_NOT_DIVISIBLE in served.reasons()


def test_lowering_summary_counts():
    mesh = mesh2(2, 4)
    plans = [lower_schedule(sched("summa"), mesh),
             lower_schedule(sched("systolic"), mesh),
             lower_schedule(sched("summa", k=130), mesh)]
    s = lowering_summary(plans)
    assert s["modes"] == {"summa": 2, "auto": 1}
    assert s["degrade_reasons"] == {lower.NON_SQUARE_SYSTOLIC: 1,
                                    lower.K_NOT_DIVISIBLE: 1}
    assert s["degraded"] == 1 and s["silent_auto_degrades"] == 0
    assert s["total"] == 3


def test_describe_is_informative():
    ep = lower_schedule(sched("systolic"), mesh2(2, 4))
    text = ep.describe()
    assert "systolic" in text and "summa" in text
    assert lower.NON_SQUARE_SYSTOLIC in text


# ---------------------------------------------------------------------------
# two-level fields: InnerKernel / overlap through the lowering
# ---------------------------------------------------------------------------

def test_inner_kernel_and_overlap_carried_through():
    ik = InnerKernel(64, 64, 32, dtype="float32")
    s = dataclasses.replace(sched("summa"), inner_kernel=ik, overlap=True)
    ep = lower_schedule(s, mesh2(2, 2))
    assert ep.mode == "summa" and not ep.fallbacks
    assert ep.inner_kernel == ik and ep.overlap is True
    assert "ik=" in ep.describe() and "overlap" in ep.describe()
    d = ep.to_dict()
    assert d["inner_kernel"] == ik.to_dict() and d["overlap"] is True


def test_oversized_inner_kernel_demotes_not_degrades():
    """A kernel whose working set busts the VMEM budget drops to the
    XLA-picked local GEMM with a recorded reason — mode unchanged (the
    scatter_m_indivisible idiom)."""
    big = InnerKernel(2048, 2048, 2048, dtype="float32")
    assert big.working_set_bytes() > INNER_VMEM_BUDGET
    ep = lower_schedule(dataclasses.replace(sched("summa"), inner_kernel=big),
                        mesh2(2, 2))
    assert ep.mode == "summa" and not ep.degraded
    assert ep.inner_kernel is None
    assert ep.reasons() == (lower.INNER_KERNEL_TOO_LARGE,)


def test_auto_landing_sheds_inner_level():
    """A degrade to auto drops kernel and overlap without an extra reason
    — auto has no mode body to honor them, and the degrade itself is
    already recorded."""
    ik = InnerKernel(64, 64, 32, dtype="float32")
    s = dataclasses.replace(sched("summa", k=130), inner_kernel=ik,
                            overlap=True)
    ep = lower_schedule(s, mesh2(2, 2))
    assert ep.mode == "auto" and ep.degraded
    assert ep.inner_kernel is None and ep.overlap is False
    assert ep.reasons() == (lower.K_NOT_DIVISIBLE,)


# ---------------------------------------------------------------------------
# execution parity vs auto (multi-device subprocess)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PARITY_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.gemm import dit_gemm
    from repro.core.lower import lower_schedule
    from repro.core.schedule import GEMMShape, Schedule, Tiling

    rng = np.random.default_rng(0)
    M, N, K = 64, 96, 128
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    ref = np.asarray(a @ b)

    def run(mesh, sched):
        ep = lower_schedule(sched, mesh, "data", "model", shape=(M, N, K))
        out = np.asarray(jax.jit(
            lambda x, y: dit_gemm(x, y, mesh, plan=sched))(a, b))
        auto = np.asarray(jax.jit(
            lambda x, y: dit_gemm(x, y, mesh, mode="auto"))(a, b))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(out, auto, rtol=1e-4, atol=1e-4)
        return ep

    CASES = [
        ("summa", dict()),
        ("systolic", dict()),
        ("baseline", dict()),
        ("splitk_summa", dict(gk=2, owner="round_robin")),
        ("splitk_summa", dict(gk=2, owner="first")),
        ("splitk_summa", dict(gk=8, owner="round_robin")),  # 1-D collapse
        ("systolic_over_summa", dict()),
        ("summa_over_systolic", dict()),
    ]
    for mesh_shape in ((2, 2), (2, 4), (4, 4)):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        for df, kw in CASES:
            sched = Schedule(GEMMShape(M, N, K),
                             Tiling(2, 2, kw.get("gk", 1), tk=32), df,
                             reduce_owner=kw.get("owner", "first"),
                             inner=(2, 2))
            ep = run(mesh, sched)
            assert not ep.degraded, (mesh_shape, df, ep.describe())
            # Fig. 6c runs its OWN mode where the outer ring fits
            if df == "systolic_over_summa" and mesh_shape == (4, 4):
                assert ep.mode == "outer_systolic", ep.describe()
            print("OK", mesh_shape, df, "->", ep.mode)

    # ROADMAP acceptance: a tuned gk>1 schedule executes TRUE 3-D split-K
    # on the 8-device mesh (not the 1-D collapse), matching auto
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))
    s3d = Schedule(GEMMShape(M, N, K), Tiling(2, 2, 2, tk=32),
                   "splitk_summa", reduce_owner="round_robin")
    ep = lower_schedule(s3d, mesh8, "data", "model", shape=(M, N, K))
    assert ep.mode == "splitk_summa" and not ep.fallbacks, ep.describe()
    assert ep.view.axis_sizes(mesh8) == {"data": 2, "model": 2, "splitk": 2}
    run(mesh8, s3d)
    print("OK 3-D splitk on 8 devices")

    # outer-systolic with degenerate (1, 1) inner groups IS outer Cannon:
    # the smallest mesh that exercises the group-level ring (2x2 outer)
    mesh4 = jax.make_mesh((2, 2), ("data", "model"))
    s6c_min = Schedule(GEMMShape(M, N, K), Tiling(2, 2, 1, tk=32),
                       "systolic_over_summa", inner=(1, 1))
    ep = lower_schedule(s6c_min, mesh4, "data", "model", shape=(M, N, K))
    assert ep.mode == "outer_systolic" and not ep.fallbacks, ep.describe()
    run(mesh4, s6c_min)
    print("OK outer_systolic (1x1 inner) on 2x2")

    # the new modes are reverse-differentiable (routed training)
    ones = jnp.ones((M, N), jnp.float32)
    mesh16 = jax.make_mesh((4, 4), ("data", "model"))
    for df, gk, mesh in (("splitk_summa", 2, mesh8),
                         ("summa_over_systolic", 1, mesh8),
                         ("systolic_over_summa", 1, mesh16),
                         ("systolic_over_summa", 1, mesh4)):
        sched = Schedule(GEMMShape(M, N, K), Tiling(2, 2, gk, tk=32), df,
                         reduce_owner="round_robin",
                         inner=(1, 1) if mesh is mesh4 else (2, 2))
        ep = lower_schedule(sched, mesh, "data", "model", shape=(M, N, K))
        ga, gb = jax.grad(
            lambda x, y, s=sched, m=mesh: dit_gemm(x, y, m, plan=s).sum(),
            argnums=(0, 1))(a, b)
        np.testing.assert_allclose(np.asarray(ga), np.asarray(ones @ b.T),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(a.T @ ones),
                                   rtol=1e-4, atol=1e-4)
        print("OK grad", df, "->", ep.mode)
    print("ALL_OK")
""")


@pytest.mark.slow
def test_exec_parity_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", PARITY_BODY], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout


ROUTED_6C_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.schedule import GEMMShape
    from repro.deploy import Planner
    from repro.hw.config import tpu_pod_as_accelerator
    from repro.models import shard_ctx
    from repro.models.matmul import pmm
    from repro.models.shard_ctx import GemmContext

    # a REAL Fig. 6c tune: the restricted search must enumerate and price
    # systolic_over_summa candidates (autotuner hierarchical enumeration)
    planner = Planner(tpu_pod_as_accelerator((4, 4)), elem_bytes=4,
                      max_candidates=12, dataflows=["systolic_over_summa"])
    shape = GEMMShape(256, 256, 512)
    plans = planner.batch_tune([shape])
    assert plans[shape].schedule.dataflow == "systolic_over_summa"

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 128, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    ctx = GemmContext(mesh=mesh, planner=planner)
    with shard_ctx.gemm_context(ctx):
        routed = jax.jit(lambda x, w: pmm(x, w, tag="fig6c"))(x, w)

    # the tuned composition survives pmm -> lower_schedule -> dit_gemm:
    # the stats record the executed mode, with no degrade of any kind
    assert ctx.stats.hits == 1, ctx.stats.describe()
    assert ctx.stats.modes == {"outer_systolic": 1}, ctx.stats.describe()
    assert not ctx.stats.degrades and ctx.stats.silent_degrades == 0
    np.testing.assert_allclose(np.asarray(routed), np.asarray(x @ w),
                               rtol=1e-3, atol=1e-3)
    print("stats:", ctx.stats.describe())
    print("ALL_OK")
""")


@pytest.mark.slow
def test_fig6c_tuned_schedule_routes_to_outer_systolic():
    """End to end: a schedule tuned under the Fig. 6c restriction reaches
    the `outer_systolic` mode through the pmm routed-dispatch path, and the
    context stats record it (the mode histogram launchers report)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", ROUTED_6C_BODY], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (f"stdout:\n{proc.stdout}\n"
                                  f"stderr:\n{proc.stderr}")
    assert "ALL_OK" in proc.stdout
